//! Small statistics helpers used by experiments and the executive:
//! summary stats, histograms (Fig. 12) and response-time variability
//! measures (Fig. 11: max-mean, mean-min, average relative range).

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
}

impl Summary {
    /// `None` for an empty sample — and for a sample containing any
    /// non-finite observation. A NaN used to slip through the
    /// `min`/`max` folds unchanged (both comparisons are false) and
    /// emit a `min=inf/max=-inf`-corrupted row; an infinity poisons
    /// mean and std the same way. Callers that can produce non-finite
    /// samples must filter (and account for) them first.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() || xs.iter().any(|x| !x.is_finite()) {
            return None;
        }
        let n = xs.len();
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
            sum += x;
        }
        let mean = sum / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary { n, min, max, mean, std: var.sqrt() })
    }

    /// Fig. 11's "Max-Mean" error bar (deviation above the mean).
    pub fn above(&self) -> f64 {
        self.max - self.mean
    }

    /// Fig. 11's "Mean-Min" error bar (deviation below the mean).
    pub fn below(&self) -> f64 {
        self.mean - self.min
    }

    /// Fig. 11's "(Max-Min)/Max" relative-range variability measure.
    pub fn relative_range(&self) -> f64 {
        if self.max == 0.0 {
            0.0
        } else {
            (self.max - self.min) / self.max
        }
    }
}

/// Fixed-width histogram (used for the Fig. 12 ε distribution).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<usize>,
    pub underflow: usize,
    pub overflow: usize,
    /// Non-finite samples (NaN, ±inf). A NaN used to be silently filed
    /// into bin 0: both range comparisons are false, and
    /// `(NaN as usize) == 0`.
    pub invalid: usize,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, invalid: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if !x.is_finite() {
            self.invalid += 1;
        } else if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let k = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[k.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.bins.iter().sum::<usize>() + self.underflow + self.overflow + self.invalid
    }

    pub fn bin_edges(&self, k: usize) -> (f64, f64) {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        (self.lo + k as f64 * w, self.lo + (k + 1) as f64 * w)
    }
}

/// Percentile (nearest-rank) of a sample; `p` in [0, 100].
///
/// Sorts by `total_cmp`, so a NaN sample never panics the comparator —
/// NaNs order at the extremes (positive NaN after +inf, negative NaN
/// before −inf) and never shuffle the finite ranks. Callers wanting
/// NaN-free percentiles must filter first (the serve counters only
/// ever record finite latencies).
pub fn percentile(xs: &mut [f64], p: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    xs.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * xs.len() as f64).ceil() as usize;
    Some(xs[rank.clamp(1, xs.len()) - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.mean, 2.5);
        assert!((s.std - 1.1180339887).abs() < 1e-9);
    }

    #[test]
    fn summary_empty() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn fig11_measures() {
        let s = Summary::of(&[2.0, 4.0, 10.0]).unwrap();
        assert!((s.above() - (10.0 - 16.0 / 3.0)).abs() < 1e-12);
        assert!((s.below() - (16.0 / 3.0 - 2.0)).abs() < 1e-12);
        assert!((s.relative_range() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn relative_range_zero_max() {
        let s = Summary::of(&[0.0, 0.0]).unwrap();
        assert_eq!(s.relative_range(), 0.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.bins.iter().all(|&c| c == 1));
        h.add(-1.0);
        h.add(10.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn histogram_edges() {
        let h = Histogram::new(0.0, 10.0, 5);
        assert_eq!(h.bin_edges(0), (0.0, 2.0));
        assert_eq!(h.bin_edges(4), (8.0, 10.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile(&mut xs, 30.0), Some(20.0));
        assert_eq!(percentile(&mut xs, 100.0), Some(50.0));
        assert_eq!(percentile(&mut xs, 0.0), Some(15.0));
    }

    #[test]
    fn summary_with_non_finite_sample_is_none() {
        // Regression: a NaN sample used to slip through the min/max
        // folds and emit a min=inf/max=-inf-corrupted row.
        assert!(Summary::of(&[1.0, f64::NAN, 3.0]).is_none());
        assert!(Summary::of(&[f64::NAN]).is_none());
        assert!(Summary::of(&[1.0, f64::INFINITY]).is_none());
        assert!(Summary::of(&[f64::NEG_INFINITY, 2.0]).is_none());
        // Finite samples are unaffected.
        assert!(Summary::of(&[1.0, 2.0]).is_some());
    }

    #[test]
    fn histogram_counts_non_finite_as_invalid() {
        // Regression: NaN used to land in bin 0 (`(NaN as usize) == 0`
        // after both range comparisons are false).
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(f64::NAN);
        h.add(f64::INFINITY);
        h.add(f64::NEG_INFINITY);
        assert_eq!(h.invalid, 3);
        assert_eq!(h.bins[0], 0, "NaN must not be filed into bin 0");
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
        assert_eq!(h.total(), 3, "invalid samples still count in total()");
        h.add(0.5);
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn percentile_with_nan_does_not_panic() {
        // `partial_cmp().unwrap()` used to panic here; total_cmp orders
        // (positive) NaN past +inf, leaving the finite ranks intact.
        let mut xs = vec![2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(percentile(&mut xs, 50.0), Some(2.0));
        let p100 = percentile(&mut xs, 100.0).unwrap();
        assert!(p100.is_nan(), "NaN sorts to the top rank");
    }
}
