//! Deterministic PCG-XSH-RR 64/32 pseudo-random generator.
//!
//! The offline crate set has no `rand`, so experiments, the taskset
//! generator and the property-test harness all share this small,
//! seedable, reproducible PRNG (O'Neill 2014, the reference PCG32
//! stream). Every experiment records its seed, so all figures are
//! exactly reproducible.

/// PCG32: 64-bit state, 64-bit stream, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo <= hi);
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive), unbiased via rejection.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        // Lemire-style rejection to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % span) - (u64::MAX % span == span - 1) as u64;
        loop {
            let v = self.next_u64();
            if v <= zone || zone == u64::MAX {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Choose a random element index weighted uniformly.
    pub fn choose_index(&mut self, len: usize) -> usize {
        debug_assert!(len > 0);
        self.range_usize(0, len - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Pcg32::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn range_u64_inclusive_bounds_hit() {
        let mut r = Pcg32::seeded(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 9);
            assert!((3..=9).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 9;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn range_u64_singleton() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..100 {
            assert_eq!(r.range_u64(5, 5), 5);
        }
    }

    #[test]
    fn range_u64_uniformity_coarse() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.range_u64(0, 9) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Pcg32::seeded(17);
        assert!((0..100).all(|_| !r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}
