//! Minimal error plumbing for the I/O-facing layers (`runtime/`,
//! `coordinator/workload`). The offline crate set has no `anyhow`, so
//! this provides the 10% of it we use: a string-backed [`Error`], the
//! [`err!`](crate::err) constructor macro, and `.context(..)` /
//! `.with_context(..)` adapters on `Result` and `Option`.

use std::fmt;

/// A string-backed error. Construct with [`Error::msg`] or the
/// [`err!`](crate::err) macro.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Crate-local result type (anyhow::Result analog).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-style formatted error constructor.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn err_macro_formats() {
        let e = crate::err!("bad value {} at line {}", 7, 3);
        assert_eq!(e.to_string(), "bad value 7 at line 3");
    }

    #[test]
    fn result_context_chains() {
        let r: std::result::Result<(), std::num::ParseIntError> =
            "x".parse::<i32>().map(|_| ());
        let e = r.context("parsing x").unwrap_err();
        assert!(e.to_string().starts_with("parsing x: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(4u8).context("missing").unwrap(), 4);
    }

    #[test]
    fn with_context_lazy() {
        let ok: Result<u8, Error> = Ok(1);
        let v = ok.with_context(|| unreachable!("not evaluated on Ok"));
        assert_eq!(v.unwrap(), 1);
    }
}
