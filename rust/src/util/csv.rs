//! Minimal CSV writer for experiment outputs (`results/*.csv`).
//! Each experiment harness records its rows here so figures can be
//! re-plotted externally; values are quoted only when needed.

use std::fs;
use std::io::Write;
use std::path::Path;

/// A CSV table under construction.
#[derive(Debug, Default, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> CsvTable {
        CsvTable { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    fn escape(cell: &str) -> String {
        // RFC 4180: quote on separator, quote, or EITHER line-break
        // byte — a bare `\r` corrupts the row for strict readers.
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') || cell.contains('\r') {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        s.push_str(
            &self.header.iter().map(|c| Self::escape(c)).collect::<Vec<_>>().join(","),
        );
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| Self::escape(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_string(), "a,b\n1,2\n");
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let mut t = CsvTable::new(vec!["x"]);
        t.row(vec!["he,llo"]);
        t.row(vec!["say \"hi\""]);
        let s = t.to_string();
        assert!(s.contains("\"he,llo\""));
        assert!(s.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn escapes_bare_carriage_returns() {
        // Regression: a cell holding a bare `\r` (no `\n`) used to be
        // emitted unquoted, splitting the row for strict CSV readers.
        let mut t = CsvTable::new(vec!["x"]);
        t.row(vec!["a\rb"]);
        t.row(vec!["a\r\nb"]);
        let s = t.to_string();
        assert!(s.contains("\"a\rb\""), "bare CR not quoted: {s:?}");
        assert!(s.contains("\"a\r\nb\""), "CRLF not quoted: {s:?}");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn writes_file() {
        let mut t = CsvTable::new(vec!["a"]);
        t.row(vec!["1"]);
        let p = std::env::temp_dir().join("gcaps_csv_test/out.csv");
        t.write(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a\n1\n");
        let _ = std::fs::remove_dir_all(p.parent().unwrap());
    }
}
