//! Artifact manifest parsing (`artifacts/manifest.tsv`, written by
//! `python/compile/aot.py`): one row per workload —
//! `name<TAB>dtype:shape,dtype:shape<TAB>description`.

use std::path::Path;

use crate::err;
use crate::util::error::{Context, Result};

/// One input tensor's signature.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl InputSpec {
    /// Parse `"float32:128x128"`.
    pub fn parse(s: &str) -> Result<InputSpec> {
        let (dtype, shape_s) =
            s.split_once(':').ok_or_else(|| err!("bad input spec {s:?}"))?;
        let shape = shape_s
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        if shape.is_empty() || shape.contains(&0) {
            return Err(err!("bad shape in {s:?}"));
        }
        Ok(InputSpec { dtype: dtype.to_string(), shape })
    }
}

/// One workload row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub name: String,
    pub inputs: Vec<InputSpec>,
    pub description: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub workloads: Vec<WorkloadSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut workloads = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut cols = line.split('\t');
            let name = cols
                .next()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| err!("line {}: missing name", lineno + 1))?;
            let inputs_s = cols
                .next()
                .ok_or_else(|| err!("line {}: missing inputs", lineno + 1))?;
            let description = cols.next().unwrap_or("").to_string();
            let inputs = inputs_s
                .split(',')
                .map(InputSpec::parse)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("line {}", lineno + 1))?;
            workloads.push(WorkloadSpec { name: name.to_string(), inputs, description });
        }
        if workloads.is_empty() {
            return Err(err!("empty manifest"));
        }
        Ok(Manifest { workloads })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&WorkloadSpec> {
        self.workloads.iter().find(|w| w.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_input_spec() {
        let s = InputSpec::parse("float32:128x128").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.shape, vec![128, 128]);
        let s = InputSpec::parse("int32:65536").unwrap();
        assert_eq!(s.shape, vec![65536]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(InputSpec::parse("float32").is_err());
        assert!(InputSpec::parse("float32:0x4").is_err());
        assert!(InputSpec::parse("float32:axb").is_err());
    }

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(
            "mmul_small\tfloat32:128x128,float32:128x128\ttask 2\n\
             histogram\tint32:65536\ttask 1\n",
        )
        .unwrap();
        assert_eq!(m.workloads.len(), 2);
        assert_eq!(m.get("histogram").unwrap().inputs[0].dtype, "int32");
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn parse_manifest_skips_blank_lines() {
        let m = Manifest::parse("a\tfloat32:4\tx\n\n").unwrap();
        assert_eq!(m.workloads.len(), 1);
    }

    #[test]
    fn empty_manifest_rejected() {
        assert!(Manifest::parse("").is_err());
    }
}
