//! PJRT runtime: loads the AOT artifacts produced by `python/compile/`
//! (HLO text + manifest) and executes them on the PJRT CPU client from
//! the live executive's request path. Python never runs here.
//!
//! One artifact execution = one "kernel launch" inside a GPU segment of
//! the paper's model; the case-study tasks issue sequences of launches
//! through the GCAPS arbiter exactly as Listing 1's CUDA calls would.
//!
//! The real implementation needs a vendored `xla` crate and is gated
//! behind the `pjrt` cargo feature. The default (offline) build compiles
//! a std-only stub with the identical API whose `load_dir` always
//! errors, so the DES, analyses and experiment sweeps — everything
//! except `gcaps live` — work without the PJRT toolchain.

pub mod registry;

pub use registry::{InputSpec, Manifest, WorkloadSpec};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;
    use std::time::{Duration, Instant};

    use crate::err;
    use crate::util::error::{Context, Error, Result};
    use crate::util::rng::Pcg32;

    use super::{InputSpec, Manifest};

    fn xe(e: impl std::fmt::Display) -> Error {
        Error::msg(e.to_string())
    }

    /// A compiled workload with pre-built deterministic input literals.
    struct Loaded {
        exe: xla::PjRtLoadedExecutable,
        inputs: Vec<xla::Literal>,
    }

    /// The runtime: a PJRT CPU client plus every compiled workload.
    pub struct Runtime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        loaded: HashMap<String, Loaded>,
    }

    fn build_literal(spec: &InputSpec, rng: &mut Pcg32) -> Result<xla::Literal> {
        let n: usize = spec.shape.iter().product::<usize>().max(1);
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match spec.dtype.as_str() {
            "float32" => {
                let data: Vec<f32> =
                    (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
                xla::Literal::vec1(&data)
            }
            "int32" => {
                let data: Vec<i32> =
                    (0..n).map(|_| rng.range_u64(0, 255) as i32).collect();
                xla::Literal::vec1(&data)
            }
            other => return Err(err!("unsupported artifact dtype {other}")),
        };
        if spec.shape.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(&dims).map_err(xe).context("reshape input literal")
        }
    }

    impl Runtime {
        /// Load every workload listed in `<dir>/manifest.tsv`, compiling
        /// the HLO text artifacts on the PJRT CPU client.
        pub fn load_dir(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(&dir.join("manifest.tsv"))?;
            let client =
                xla::PjRtClient::cpu().map_err(xe).context("create PJRT CPU client")?;
            let mut loaded = HashMap::new();
            let mut rng = Pcg32::seeded(0x9c0ffee);
            for w in &manifest.workloads {
                let path = dir.join(format!("{}.hlo.txt", w.name));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
                )
                .map_err(xe)
                .with_context(|| format!("parse HLO text for {}", w.name))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(xe)
                    .with_context(|| format!("compile {}", w.name))?;
                let inputs = w
                    .inputs
                    .iter()
                    .map(|s| build_literal(s, &mut rng))
                    .collect::<Result<Vec<_>>>()?;
                loaded.insert(w.name.clone(), Loaded { exe, inputs });
            }
            Ok(Runtime { client, loaded })
        }

        /// Names of the loaded workloads (sorted for determinism).
        pub fn workloads(&self) -> Vec<String> {
            let mut v: Vec<String> = self.loaded.keys().cloned().collect();
            v.sort();
            v
        }

        /// Execute one launch of `name` synchronously; returns the
        /// wall-clock execution time. This is the hot path — no
        /// allocation beyond what PJRT itself does.
        pub fn exec(&self, name: &str) -> Result<Duration> {
            let l = self
                .loaded
                .get(name)
                .ok_or_else(|| err!("unknown workload {name}"))?;
            let start = Instant::now(); // gcaps-lint: allow(wall-clock) -- real launch latency
            let result = l.exe.execute::<xla::Literal>(&l.inputs).map_err(xe)?;
            // Block until the output is materialised (the launch is async).
            let _out = result[0][0].to_literal_sync().map_err(xe)?;
            Ok(start.elapsed())
        }

        /// Execute and return the first output as f32s (for validation).
        pub fn exec_values(&self, name: &str) -> Result<Vec<f32>> {
            let l = self
                .loaded
                .get(name)
                .ok_or_else(|| err!("unknown workload {name}"))?;
            let result = l.exe.execute::<xla::Literal>(&l.inputs).map_err(xe)?;
            let out = result[0][0].to_literal_sync().map_err(xe)?.to_tuple1().map_err(xe)?;
            out.to_vec::<f32>().map_err(xe)
        }

        /// Median launch time of `name` over `reps` runs (profiling; used
        /// to derive the case-study G^e budgets like the paper's Table 4).
        pub fn profile(&self, name: &str, reps: usize) -> Result<Duration> {
            let mut times: Vec<Duration> = (0..reps)
                .map(|_| self.exec(name))
                .collect::<Result<Vec<_>>>()?;
            times.sort();
            Ok(times[times.len() / 2])
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;
    use std::time::Duration;

    use crate::err;
    use crate::util::error::Result;

    fn unavailable<T>(what: &str) -> Result<T> {
        Err(err!(
            "gcaps was built without the `pjrt` feature; {what} needs the \
             PJRT toolchain. Enabling `--features pjrt` additionally \
             requires wiring a vendored `xla` crate into rust/Cargo.toml \
             (an optional path dependency cannot ship by default: cargo \
             rejects manifests whose dep paths do not exist)"
        ))
    }

    /// API-compatible stand-in for the PJRT runtime. `load_dir` always
    /// fails, so callers take their artifacts-missing path; the other
    /// methods exist only so dependent code typechecks.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn load_dir(dir: &Path) -> Result<Runtime> {
            unavailable(&format!("loading artifacts from {}", dir.display()))
        }

        pub fn workloads(&self) -> Vec<String> {
            Vec::new()
        }

        pub fn exec(&self, name: &str) -> Result<Duration> {
            unavailable(&format!("launching {name}"))
        }

        pub fn exec_values(&self, name: &str) -> Result<Vec<f32>> {
            unavailable(&format!("launching {name}"))
        }

        pub fn profile(&self, name: &str, _reps: usize) -> Result<Duration> {
            unavailable(&format!("profiling {name}"))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Default artifacts directory: `$GCAPS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("GCAPS_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| "artifacts".into())
}
