//! Random taskset generation per Table 3 of the paper (§7.1):
//!
//! | Number of CPUs                         | 4            |
//! | Number of tasks per CPU                | [3, 6]       |
//! | Ratio of GPU-using tasks               | [40, 60] %   |
//! | Utilization per CPU                    | [0.4, 0.6]   |
//! | Task period                            | [30, 500] ms |
//! | Number of GPU segments per task        | [1, 3]       |
//! | Ratio of GPU exec. to CPU exec. (G/C)  | [0.2, 2]     |
//! | Ratio of GPU misc. in GPU exec. (G^m/G)| [0.1, 0.3]   |
//! | Runlist update cost (ε)                | 1 ms         |
//!
//! Pipeline: per-CPU UUniFast utilizations → per-task period/segment
//! randomization → RM priority assignment → WFD re-allocation for load
//! balance → optional best-effort designation (Fig. 8f).

use crate::model::{GpuSegment, Platform, Task, TaskSet, Time, WaitMode};
use crate::taskgen::uunifast::uunifast;
use crate::util::rng::Pcg32;

/// Generation parameters (defaults = Table 3).
#[derive(Debug, Clone)]
pub struct GenParams {
    pub num_cpus: usize,
    pub tasks_per_cpu: (usize, usize),
    pub gpu_task_ratio: (f64, f64),
    pub util_per_cpu: (f64, f64),
    pub period_ms: (f64, f64),
    pub gpu_segments: (usize, usize),
    pub g_to_c_ratio: (f64, f64),
    pub gm_in_g_ratio: (f64, f64),
    /// Fraction of tasks designated best-effort (Fig. 8f); 0 by default.
    pub best_effort_ratio: f64,
    /// Per-segment fine-grain SM fraction band, in integer percent.
    /// The default `(100, 100)` is the serial whole-context model and
    /// draws nothing from the RNG, so every legacy stream (and the
    /// memoized params hash) is untouched; any other band draws one
    /// uniform fraction per GPU segment from `[lo, hi]`.
    pub par_range: (u32, u32),
    /// Wait mode applied to every task (each analysis mode is evaluated
    /// on a matching taskset, as in the paper).
    pub mode: WaitMode,
    pub platform: Platform,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams {
            num_cpus: 4,
            tasks_per_cpu: (3, 6),
            gpu_task_ratio: (0.4, 0.6),
            util_per_cpu: (0.4, 0.6),
            period_ms: (30.0, 500.0),
            gpu_segments: (1, 3),
            g_to_c_ratio: (0.2, 2.0),
            gm_in_g_ratio: (0.1, 0.3),
            best_effort_ratio: 0.0,
            par_range: (100, 100),
            mode: WaitMode::SelfSuspend,
            platform: Platform::default(),
        }
    }
}

/// Split `total` into `n` random positive parts (uniform stick-breaking).
fn split_random(rng: &mut Pcg32, total: Time, n: usize) -> Vec<Time> {
    assert!(n > 0);
    if n == 1 {
        return vec![total];
    }
    // Draw n weights, normalize; integer-round with remainder to the last.
    let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.2, 1.0)).collect();
    let wsum: f64 = weights.iter().sum();
    let mut parts: Vec<Time> = weights
        .iter()
        .take(n - 1)
        .map(|w| ((w / wsum) * total as f64).floor() as Time)
        .collect();
    let used: Time = parts.iter().sum();
    parts.push(total.saturating_sub(used));
    parts
}

/// Generate one random taskset.
pub fn generate(rng: &mut Pcg32, p: &GenParams) -> TaskSet {
    let mut tasks: Vec<Task> = Vec::new();
    let gpu_ratio = rng.range_f64(p.gpu_task_ratio.0, p.gpu_task_ratio.1);

    for cpu in 0..p.num_cpus {
        let n = rng.range_usize(p.tasks_per_cpu.0, p.tasks_per_cpu.1);
        let u_total = rng.range_f64(p.util_per_cpu.0, p.util_per_cpu.1);
        let utils = uunifast(rng, n, u_total);
        // Exact GPU-task count for this CPU, rounding the ratio.
        let n_gpu = ((n as f64 * gpu_ratio).round() as usize).min(n);
        let mut is_gpu: Vec<bool> = (0..n).map(|i| i < n_gpu).collect();
        rng.shuffle(&mut is_gpu);

        for (k, util) in utils.into_iter().enumerate() {
            let period_ms = rng.range_f64(p.period_ms.0, p.period_ms.1);
            let period: Time = (period_ms * 1000.0).round() as Time;
            // Total demand W = U * T, at least 100 µs to stay meaningful.
            let demand = ((util * period as f64).round() as Time).max(100);
            let id = tasks.len();
            let task = if is_gpu[k] {
                let rho = rng.range_f64(p.g_to_c_ratio.0, p.g_to_c_ratio.1);
                let g_total = ((demand as f64 * rho / (1.0 + rho)).round() as Time)
                    .clamp(1, demand - 1);
                let c_total = demand - g_total;
                let eta_g = rng.range_usize(p.gpu_segments.0, p.gpu_segments.1);
                let g_parts = split_random(rng, g_total, eta_g);
                let gpu_segments: Vec<GpuSegment> = g_parts
                    .into_iter()
                    .map(|g| {
                        let gm_ratio = rng.range_f64(p.gm_in_g_ratio.0, p.gm_in_g_ratio.1);
                        let gm = ((g as f64 * gm_ratio).round() as Time).min(g);
                        let seg = GpuSegment::new(gm, g - gm);
                        // Serial band draws nothing — stream-identical
                        // to the pre-fine-grain generator.
                        if p.par_range == (100, 100) {
                            seg
                        } else {
                            let par = rng
                                .range_u64(p.par_range.0 as u64, p.par_range.1 as u64)
                                as u32;
                            seg.with_par(par)
                        }
                    })
                    .collect();
                let cpu_segments = split_random(rng, c_total.max(eta_g as Time + 1), eta_g + 1);
                Task {
                    id,
                    name: format!("tau{id}"),
                    period,
                    deadline: period,
                    cpu_segments,
                    gpu_segments,
                    core: cpu,
                    gpu: 0, // assigned below (WFD over engines)
                    cpu_prio: 0, // assigned below
                    gpu_prio: 0,
                    best_effort: false,
                    mode: p.mode,
                }
            } else {
                let mut t = Task::cpu_only(id, cpu, 0, demand, period);
                t.mode = p.mode;
                t
            };
            tasks.push(task);
        }
    }

    // Best-effort designation (Fig. 8f): random subset loses RT priority.
    if p.best_effort_ratio > 0.0 {
        let n_be = ((tasks.len() as f64 * p.best_effort_ratio).round() as usize)
            .min(tasks.len().saturating_sub(1));
        let mut idx: Vec<usize> = (0..tasks.len()).collect();
        rng.shuffle(&mut idx);
        for &i in idx.iter().take(n_be) {
            tasks[i].best_effort = true;
        }
    }

    assign_rm_priorities(&mut tasks);
    wfd_reallocate(&mut tasks, p.num_cpus);
    wfd_assign_gpus(&mut tasks, p.platform.num_gpus());

    TaskSet::new(
        tasks,
        Platform { num_cpus: p.num_cpus, gpus: p.platform.gpus.clone() },
    )
}

/// Rate-Monotonic priorities: shorter period = higher priority. Unique
/// values, ties broken by id. Best-effort tasks keep priority 0.
pub fn assign_rm_priorities(tasks: &mut [Task]) {
    let mut order: Vec<usize> = (0..tasks.len()).filter(|&i| !tasks[i].best_effort).collect();
    // Longest period first => lowest priority value first.
    order.sort_by(|&a, &b| {
        tasks[b].period.cmp(&tasks[a].period).then(tasks[b].id.cmp(&tasks[a].id))
    });
    for (rank, &i) in order.iter().enumerate() {
        tasks[i].cpu_prio = rank as u32 + 1;
        tasks[i].gpu_prio = rank as u32 + 1;
    }
    for t in tasks.iter_mut().filter(|t| t.best_effort) {
        t.cpu_prio = 0;
        t.gpu_prio = 0;
    }
}

/// Worst-Fit-Decreasing task-to-GPU assignment: GPU-using tasks, taken
/// in decreasing GPU utilization (G_i/T_i), land on the currently
/// least-loaded engine. Deterministic (no RNG draws — ties break by
/// id), so single-GPU generation is bit-identical to the pre-multi-GPU
/// pipeline. CPU-only tasks stay on engine 0 (the field is unused for
/// them).
pub fn wfd_assign_gpus(tasks: &mut [Task], num_gpus: usize) {
    if num_gpus <= 1 {
        for t in tasks.iter_mut() {
            t.gpu = 0;
        }
        return;
    }
    let gpu_util = |t: &Task| t.g() as f64 / t.period as f64;
    let mut order: Vec<usize> = (0..tasks.len()).filter(|&i| tasks[i].uses_gpu()).collect();
    order.sort_by(|&a, &b| {
        gpu_util(&tasks[b])
            .partial_cmp(&gpu_util(&tasks[a]))
            .unwrap()
            .then(tasks[a].id.cmp(&tasks[b].id))
    });
    let mut load = vec![0.0f64; num_gpus];
    for &i in &order {
        let g = (0..num_gpus)
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
            .unwrap();
        tasks[i].gpu = g;
        load[g] += gpu_util(&tasks[i]);
    }
}

/// Worst-Fit-Decreasing re-allocation: sort by utilization descending,
/// place each task on the currently least-loaded core (paper §7.1:
/// "re-allocate the tasks to the CPUs for load balancing with WFD").
pub fn wfd_reallocate(tasks: &mut [Task], num_cpus: usize) {
    let mut order: Vec<usize> = (0..tasks.len()).collect();
    order.sort_by(|&a, &b| {
        tasks[b]
            .utilization()
            .partial_cmp(&tasks[a].utilization())
            .unwrap()
            .then(tasks[a].id.cmp(&tasks[b].id))
    });
    let mut load = vec![0.0f64; num_cpus];
    for &i in &order {
        let core = (0..num_cpus)
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
            .unwrap();
        tasks[i].core = core;
        load[core] += tasks[i].utilization();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn generates_valid_tasksets() {
        forall("taskgen validity", 100, |rng| {
            let ts = generate(rng, &GenParams::default());
            ts.validate().map_err(|e| e)?;
            Ok(())
        });
    }

    #[test]
    fn respects_table3_structure() {
        forall("taskgen table3 bounds", 100, |rng| {
            let p = GenParams::default();
            let ts = generate(rng, &p);
            let n = ts.len();
            if !(12..=24).contains(&n) {
                return Err(format!("{n} tasks not in [12, 24]"));
            }
            for t in &ts.tasks {
                let pms = t.period as f64 / 1000.0;
                if !(29.9..=500.1).contains(&pms) {
                    return Err(format!("period {pms} ms out of range"));
                }
                if t.uses_gpu() && !(1..=3).contains(&t.eta_g()) {
                    return Err(format!("η_g = {}", t.eta_g()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gpu_ratio_in_band() {
        forall("taskgen gpu ratio", 60, |rng| {
            let ts = generate(rng, &GenParams::default());
            let ratio = ts.num_gpu_tasks() as f64 / ts.len() as f64;
            // rounding per-CPU can push slightly outside [0.4, 0.6]
            if !(0.25..=0.75).contains(&ratio) {
                return Err(format!("gpu ratio {ratio}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rm_priorities_follow_periods() {
        forall("RM order", 60, |rng| {
            let ts = generate(rng, &GenParams::default());
            for a in ts.rt_tasks() {
                for b in ts.rt_tasks() {
                    if a.period < b.period && a.cpu_prio <= b.cpu_prio {
                        return Err(format!(
                            "task {} (T = {}) prio {} <= task {} (T = {}) prio {}",
                            a.id, a.period, a.cpu_prio, b.id, b.period, b.cpu_prio
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wfd_balances_load() {
        forall("WFD balance", 60, |rng| {
            let ts = generate(rng, &GenParams::default());
            let loads: Vec<f64> =
                (0..ts.platform.num_cpus).map(|c| ts.core_utilization(c)).collect();
            let max = loads.iter().cloned().fold(f64::MIN, f64::max);
            let min = loads.iter().cloned().fold(f64::MAX, f64::min);
            // WFD keeps the spread below the largest single task's util,
            // which Table 3 bounds well under 0.6.
            if max - min > 0.61 {
                return Err(format!("load spread {} too large: {loads:?}", max - min));
            }
            Ok(())
        });
    }

    #[test]
    fn best_effort_ratio_applied() {
        let mut rng = Pcg32::seeded(42);
        let p = GenParams { best_effort_ratio: 0.4, ..Default::default() };
        let ts = generate(&mut rng, &p);
        let be = ts.be_tasks().count();
        let expect = (ts.len() as f64 * 0.4).round() as usize;
        assert_eq!(be, expect);
        ts.validate().unwrap();
    }

    #[test]
    fn split_random_conserves_total() {
        forall("split conserves", 200, |rng| {
            let total = rng.range_u64(10, 100_000);
            let n = rng.range_usize(1, 5);
            let parts = split_random(rng, total, n);
            if parts.iter().sum::<u64>() != total {
                return Err(format!("parts {parts:?} don't sum to {total}"));
            }
            if parts.len() != n {
                return Err("wrong part count".into());
            }
            Ok(())
        });
    }

    #[test]
    fn busy_mode_propagates() {
        let mut rng = Pcg32::seeded(1);
        let p = GenParams { mode: WaitMode::BusyWait, ..Default::default() };
        let ts = generate(&mut rng, &p);
        assert!(ts.tasks.iter().all(|t| t.mode == WaitMode::BusyWait));
    }

    #[test]
    fn single_gpu_platforms_pin_everything_to_engine_zero() {
        forall("single-GPU pins to 0", 50, |rng| {
            let ts = generate(rng, &GenParams::default());
            if ts.tasks.iter().any(|t| t.gpu != 0) {
                return Err("task assigned off engine 0 on a 1-GPU platform".into());
            }
            Ok(())
        });
    }

    #[test]
    fn wfd_gpu_assignment_balances_engines() {
        forall("WFD GPU balance", 60, |rng| {
            let p = GenParams {
                platform: Platform::default().with_num_gpus(2),
                ..Default::default()
            };
            let ts = generate(rng, &p);
            ts.validate()?;
            let gpu_load = |g: usize| -> f64 {
                ts.on_gpu(g).map(|t| t.g() as f64 / t.period as f64).sum()
            };
            let (l0, l1) = (gpu_load(0), gpu_load(1));
            // Worst-fit bounds the spread by the largest single task's
            // GPU utilization.
            let max_single = ts
                .tasks
                .iter()
                .filter(|t| t.uses_gpu())
                .map(|t| t.g() as f64 / t.period as f64)
                .fold(0.0, f64::max);
            if (l0 - l1).abs() > max_single + 1e-9 {
                return Err(format!("engine loads {l0:.3} vs {l1:.3} (max single {max_single:.3})"));
            }
            // With ≥ 2 GPU tasks, both engines must be populated.
            if ts.num_gpu_tasks() >= 2 && (ts.on_gpu(0).count() == 0 || ts.on_gpu(1).count() == 0)
            {
                return Err("an engine was left empty".into());
            }
            Ok(())
        });
    }

    #[test]
    fn par_range_draws_fractions_within_band() {
        forall("par band", 60, |rng| {
            let p = GenParams { par_range: (30, 70), ..Default::default() };
            let ts = generate(rng, &p);
            ts.validate()?;
            if !ts.has_fine_grain() {
                return Err("no fine-grain fraction drawn".into());
            }
            for t in &ts.tasks {
                for g in &t.gpu_segments {
                    if !(30..=70).contains(&g.par.pct()) {
                        return Err(format!("par {} outside [30, 70]", g.par.pct()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn serial_par_range_is_stream_identical_to_legacy() {
        // (100, 100) must draw nothing: the generated taskset AND the
        // RNG stream match the default generator exactly.
        let mut r1 = Pcg32::seeded(99);
        let mut r2 = Pcg32::seeded(99);
        let a = generate(&mut r1, &GenParams::default());
        let b = generate(
            &mut r2,
            &GenParams { par_range: (100, 100), ..Default::default() },
        );
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng streams diverged");
        assert_eq!(a.tasks, b.tasks);
        assert!(!b.has_fine_grain());
    }

    #[test]
    fn gpu_assignment_is_deterministic_and_draw_free() {
        // The GPU assignment must not consume RNG draws: generation
        // under 1 and 4 engines makes identical random decisions, so
        // the task structure matches field-for-field except `gpu`.
        let p1 = GenParams::default();
        let p4 = GenParams {
            platform: Platform::default().with_num_gpus(4),
            ..Default::default()
        };
        let mut r1 = Pcg32::seeded(77);
        let mut r4 = Pcg32::seeded(77);
        let a = generate(&mut r1, &p1);
        let b = generate(&mut r4, &p4);
        assert_eq!(r1.next_u64(), r4.next_u64(), "rng streams diverged");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.period, y.period);
            assert_eq!(x.cpu_segments, y.cpu_segments);
            assert_eq!(x.gpu_segments, y.gpu_segments);
            assert_eq!(x.core, y.core);
            assert_eq!(x.cpu_prio, y.cpu_prio);
        }
    }
}
