//! Random taskset generation (paper §7.1, Table 3): UUniFast utilization
//! draws, Rate-Monotonic priorities, Worst-Fit-Decreasing allocation.

pub mod generator;
pub mod uunifast;

pub use generator::{assign_rm_priorities, generate, wfd_assign_gpus, wfd_reallocate, GenParams};
pub use uunifast::uunifast;
