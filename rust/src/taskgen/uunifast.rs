//! UUniFast (Bini & Buttazzo 2005): draw n task utilizations summing to
//! a target total, uniformly over the simplex. Used per-CPU by the
//! taskset generator, exactly as in the paper's §7.1 setup.

use crate::util::rng::Pcg32;

/// Generate `n` utilizations summing to `total` (UUniFast).
pub fn uunifast(rng: &mut Pcg32, n: usize, total: f64) -> Vec<f64> {
    assert!(n > 0, "uunifast needs n > 0");
    let mut utils = Vec::with_capacity(n);
    let mut sum = total;
    for i in 1..n {
        // next_sum = sum * U^(1/(n-i)) with U uniform in (0,1)
        let next_sum = sum * rng.f64().powf(1.0 / (n - i) as f64);
        utils.push(sum - next_sum);
        sum = next_sum;
    }
    utils.push(sum);
    utils
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn sums_to_total() {
        let mut rng = Pcg32::seeded(5);
        for n in 1..10 {
            let u = uunifast(&mut rng, n, 0.5);
            let s: f64 = u.iter().sum();
            assert!((s - 0.5).abs() < 1e-12, "n = {n}: sum = {s}");
            assert_eq!(u.len(), n);
        }
    }

    #[test]
    fn all_positive_property() {
        forall("uunifast positive", 200, |rng| {
            let n = rng.range_usize(1, 12);
            let total = rng.range_f64(0.05, 0.95);
            let u = uunifast(rng, n, total);
            for (i, &v) in u.iter().enumerate() {
                if !(v >= 0.0 && v <= total + 1e-12) {
                    return Err(format!("util[{i}] = {v} out of [0, {total}]"));
                }
            }
            let s: f64 = u.iter().sum();
            if (s - total).abs() > 1e-9 {
                return Err(format!("sum {s} != {total}"));
            }
            Ok(())
        });
    }

    #[test]
    fn mean_per_task_is_total_over_n() {
        // Statistical sanity: E[u_i] = total/n.
        let mut rng = Pcg32::seeded(77);
        let n = 4;
        let total = 0.6;
        let reps = 20_000;
        let mut acc = vec![0.0; n];
        for _ in 0..reps {
            let u = uunifast(&mut rng, n, total);
            for (a, v) in acc.iter_mut().zip(u) {
                *a += v;
            }
        }
        for a in acc {
            let mean = a / reps as f64;
            assert!((mean - total / n as f64).abs() < 0.01, "mean = {mean}");
        }
    }
}
