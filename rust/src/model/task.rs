//! The GCAPS task model (paper §4).
//!
//! A task τ_i = (C_i, G_i, T_i, D_i, η_i^c, η_i^g, π_i) is an alternating
//! sequence of CPU segments and GPU segments; each GPU segment
//! G_{i,j} = (G^m_{i,j}, G^e_{i,j}) splits into miscellaneous CPU work
//! (kernel launch, driver communication) and pure GPU execution during
//! which the task busy-waits or self-suspends.
//!
//! All times are integer **microseconds** (`Time`): the RTA fixed points
//! then converge exactly and the simulator is branch-exact.

/// Time in microseconds.
pub type Time = u64;

/// Convert milliseconds (f64, as used in the paper's tables) to µs.
pub fn ms(v: f64) -> Time {
    (v * 1000.0).round() as Time
}

/// Convert µs back to ms for reporting.
pub fn to_ms(t: Time) -> f64 {
    t as f64 / 1000.0
}

/// How a task waits for pure GPU execution (paper §4, §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WaitMode {
    /// Task spins on its CPU for the duration of G^e.
    BusyWait,
    /// Task yields the CPU and is resumed on GPU completion.
    SelfSuspend,
}

/// Fine-grain SM utilization of one GPU segment, as an integer percent
/// of the engine's capacity (RTGPU-style fractional fine-grain
/// utilization, arXiv 2101.10463). `FULL` (100%) is the serial
/// whole-context model of the GCAPS paper; any smaller value declares
/// that the segment's kernels occupy only that capacity fraction, so
/// the driver may co-run it with other partial contexts while the
/// resident fractions sum to ≤ 100%. Stored raw; [`Task::validate`]
/// rejects 0 and values above 100.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SmFraction(u32);

impl SmFraction {
    /// 100%: the whole-context serial model (the default).
    pub const FULL: SmFraction = SmFraction(100);

    /// Wrap a raw percent. Not range-checked here — hostile values must
    /// survive parsing so [`Task::validate`] can report them.
    pub fn new(pct: u32) -> SmFraction {
        SmFraction(pct)
    }

    /// The raw percent value.
    pub fn pct(&self) -> u32 {
        self.0
    }

    /// Whether this is the serial whole-context fraction.
    pub fn is_full(&self) -> bool {
        self.0 >= 100
    }
}

impl Default for SmFraction {
    fn default() -> SmFraction {
        SmFraction::FULL
    }
}

/// One GPU segment: (G^m, G^e) plus its fine-grain SM fraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GpuSegment {
    /// G^m: misc CPU operations (launch, driver comms) within the segment.
    pub misc: Time,
    /// G^e: pure GPU execution (copies + kernels), no CPU intervention.
    pub exec: Time,
    /// Declared SM fraction during G^e (100% = serial whole context).
    pub par: SmFraction,
}

impl GpuSegment {
    pub fn new(misc: Time, exec: Time) -> GpuSegment {
        GpuSegment { misc, exec, par: SmFraction::FULL }
    }

    /// Builder: the same segment with a declared SM fraction.
    pub fn with_par(mut self, pct: u32) -> GpuSegment {
        self.par = SmFraction::new(pct);
        self
    }

    /// Total worst-case length of the segment (G ≤ G^m + G^e; we use the
    /// safe upper bound, as the paper's evaluation does).
    pub fn total(&self) -> Time {
        self.misc + self.exec
    }
}

/// A sporadic task with constrained deadline, preallocated to one core.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Task {
    /// Index in the taskset (stable identifier).
    pub id: usize,
    /// Human-readable name (workload name in the case study).
    pub name: String,
    /// T_i: minimum inter-arrival time.
    pub period: Time,
    /// D_i ≤ T_i: relative deadline.
    pub deadline: Time,
    /// WCETs of the η_i^c CPU segments (alternating with GPU segments).
    pub cpu_segments: Vec<Time>,
    /// The η_i^g GPU segments; empty for CPU-only tasks.
    pub gpu_segments: Vec<GpuSegment>,
    /// Preallocated CPU core (partitioned scheduling, no migration).
    pub core: usize,
    /// Assigned GPU engine (index into `Platform::gpus`). GPU segments
    /// run only on this engine; tasks on different engines share no
    /// context queue. Ignored (0) for CPU-only tasks.
    pub gpu: usize,
    /// π_i^c: CPU priority. Higher value = higher priority (rt_priority
    /// semantics). Unique across the system for real-time tasks.
    pub cpu_prio: u32,
    /// π_i^g: GPU segment priority (defaults to cpu_prio; §5.3 allows a
    /// separate assignment).
    pub gpu_prio: u32,
    /// Best-effort tasks have no real-time priority (rt_priority unset);
    /// under GCAPS they run only when no RT task holds the GPU.
    pub best_effort: bool,
    /// Busy-wait or self-suspend during pure GPU execution.
    pub mode: WaitMode,
}

impl Task {
    /// C_i: cumulative CPU segment WCET.
    pub fn c(&self) -> Time {
        self.cpu_segments.iter().sum()
    }

    /// G_i^m: cumulative misc CPU work across GPU segments.
    pub fn gm(&self) -> Time {
        self.gpu_segments.iter().map(|g| g.misc).sum()
    }

    /// G_i^e: cumulative pure GPU execution.
    pub fn ge(&self) -> Time {
        self.gpu_segments.iter().map(|g| g.exec).sum()
    }

    /// G_i: cumulative GPU segment WCET (safe bound G^m + G^e).
    pub fn g(&self) -> Time {
        self.gm() + self.ge()
    }

    /// η_i^c.
    pub fn eta_c(&self) -> usize {
        self.cpu_segments.len()
    }

    /// η_i^g.
    pub fn eta_g(&self) -> usize {
        self.gpu_segments.len()
    }

    /// Whether the task uses the GPU (η_i^g > 0).
    pub fn uses_gpu(&self) -> bool {
        !self.gpu_segments.is_empty()
    }

    /// Longest single GPU segment (G^m + G^e), for lock-based blocking
    /// bounds (MPCP / FMLP+).
    pub fn max_gpu_segment(&self) -> Time {
        self.gpu_segments.iter().map(|g| g.total()).max().unwrap_or(0)
    }

    /// Whether any GPU segment declares a fine-grain fraction below
    /// 100% — the switch between the serial whole-context model and the
    /// co-running fine-grain model. All-100% tasks must be
    /// indistinguishable from tasks written before the field existed.
    pub fn has_fine_grain(&self) -> bool {
        self.gpu_segments.iter().any(|g| !g.par.is_full())
    }

    /// Worst-case (largest) declared SM fraction over the task's GPU
    /// segments, as a percent; 100 for CPU-only tasks. The fine-grain
    /// RTA charges co-runnability against this maximum, which is sound:
    /// every actual segment fraction is ≤ it.
    pub fn fmax_pct(&self) -> u32 {
        self.gpu_segments.iter().map(|g| g.par.pct()).max().unwrap_or(100)
    }

    /// Total utilization (C_i + G_i) / T_i.
    pub fn utilization(&self) -> f64 {
        (self.c() + self.g()) as f64 / self.period as f64
    }

    /// CPU-side utilization only (C_i + G_i^m) / T_i.
    pub fn cpu_utilization(&self) -> f64 {
        (self.c() + self.gm()) as f64 / self.period as f64
    }

    /// Validate internal structure (segment alternation, deadline).
    pub fn validate(&self) -> Result<(), String> {
        if self.period == 0 {
            return Err(format!("task {}: zero period", self.id));
        }
        if self.deadline > self.period {
            return Err(format!(
                "task {}: deadline {} > period {} (constrained deadlines required)",
                self.id, self.deadline, self.period
            ));
        }
        if self.cpu_segments.is_empty() {
            return Err(format!("task {}: no CPU segments", self.id));
        }
        // Alternating structure: η_c = η_g + 1 for GPU tasks (a job starts
        // and ends on the CPU), η_g = 0 for CPU-only tasks.
        if self.uses_gpu() && self.cpu_segments.len() != self.gpu_segments.len() + 1 {
            return Err(format!(
                "task {}: η_c = {} but η_g = {} (need η_c = η_g + 1)",
                self.id,
                self.cpu_segments.len(),
                self.gpu_segments.len()
            ));
        }
        for (j, g) in self.gpu_segments.iter().enumerate() {
            let p = g.par.pct();
            if p == 0 || p > 100 {
                return Err(format!(
                    "task {}: GPU segment {} declares par = {}% (need 1..=100)",
                    self.id, j, p
                ));
            }
        }
        Ok(())
    }

    /// Builder for tests and examples: CPU-only task.
    pub fn cpu_only(
        id: usize,
        core: usize,
        prio: u32,
        c: Time,
        period: Time,
    ) -> Task {
        Task {
            id,
            name: format!("tau{id}"),
            period,
            deadline: period,
            cpu_segments: vec![c],
            gpu_segments: vec![],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu_task() -> Task {
        Task {
            id: 0,
            name: "t".into(),
            period: ms(80.0),
            deadline: ms(80.0),
            cpu_segments: vec![ms(2.0), ms(4.0), ms(3.0)],
            gpu_segments: vec![
                GpuSegment::new(ms(2.0), ms(4.0)),
                GpuSegment::new(ms(2.0), ms(2.0)),
            ],
            core: 0,
            gpu: 0,
            cpu_prio: 10,
            gpu_prio: 10,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn table2_tau1_aggregates() {
        // τ_1 of Table 2: C = 9, G^m = 4, G^e = 6, G = 10.
        let t = gpu_task();
        assert_eq!(t.c(), ms(9.0));
        assert_eq!(t.gm(), ms(4.0));
        assert_eq!(t.ge(), ms(6.0));
        assert_eq!(t.g(), ms(10.0));
        assert_eq!(t.eta_c(), 3);
        assert_eq!(t.eta_g(), 2);
        assert!(t.uses_gpu());
        t.validate().unwrap();
    }

    #[test]
    fn utilization() {
        let t = gpu_task();
        assert!((t.utilization() - 19.0 / 80.0).abs() < 1e-9);
        assert!((t.cpu_utilization() - 13.0 / 80.0).abs() < 1e-9);
    }

    #[test]
    fn max_gpu_segment() {
        let t = gpu_task();
        assert_eq!(t.max_gpu_segment(), ms(6.0));
    }

    #[test]
    fn cpu_only_valid() {
        let t = Task::cpu_only(1, 0, 5, ms(40.0), ms(150.0));
        assert!(!t.uses_gpu());
        assert_eq!(t.g(), 0);
        t.validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_alternation() {
        let mut t = gpu_task();
        t.cpu_segments.pop();
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_unconstrained_deadline() {
        let mut t = gpu_task();
        t.deadline = t.period + 1;
        assert!(t.validate().is_err());
    }

    #[test]
    fn ms_roundtrip() {
        assert_eq!(ms(1.5), 1500);
        assert_eq!(to_ms(2500), 2.5);
    }

    #[test]
    fn default_fraction_is_full_serial() {
        let t = gpu_task();
        assert!(!t.has_fine_grain());
        assert_eq!(t.fmax_pct(), 100);
        assert!(GpuSegment::new(1, 2).par.is_full());
        assert_eq!(SmFraction::default(), SmFraction::FULL);
        t.validate().unwrap();
    }

    #[test]
    fn fine_grain_fraction_detected_and_bounded() {
        let mut t = gpu_task();
        t.gpu_segments[1] = t.gpu_segments[1].with_par(40);
        assert!(t.has_fine_grain());
        assert_eq!(t.fmax_pct(), 100); // segment 0 is still serial
        t.gpu_segments[0] = t.gpu_segments[0].with_par(70);
        assert_eq!(t.fmax_pct(), 70);
        t.validate().unwrap();
    }

    #[test]
    fn validate_rejects_zero_and_oversized_fractions() {
        for bad in [0u32, 101, u32::MAX] {
            let mut t = gpu_task();
            t.gpu_segments[0] = t.gpu_segments[0].with_par(bad);
            assert!(t.validate().is_err(), "par = {bad} must be rejected");
        }
        let mut t = gpu_task();
        t.gpu_segments[0] = t.gpu_segments[0].with_par(1);
        t.gpu_segments[1] = t.gpu_segments[1].with_par(100);
        t.validate().unwrap();
    }
}
