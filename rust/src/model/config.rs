//! Plain-text taskset format: lets users analyse/simulate their own
//! systems without writing Rust (the offline crate set has no serde, so
//! this is a small hand-rolled `key=value` section format).
//!
//! ```text
//! # comments with '#'
//! [platform]
//! num_cpus = 4
//! num_gpus = 2              # optional, defaults to 1 (the paper's platform)
//! epsilon_us = 1000         # applied to every GPU engine
//! theta_us = 200
//! slice_us = 1024
//!
//! [gpu]                     # optional: one section per engine for
//! profile = xavier_nx       # optional board preset (xavier_nx |
//! epsilon_us = 1000         # orin_nano) — put it first, later keys
//! theta_us = 200            # override it. Sections override the scalar
//! slice_us = 1024           # keys; section count must match num_gpus
//!                           # when both are given.
//!
//! [task]
//! name = camera
//! core = 0
//! gpu = 0                   # optional GPU engine, defaults to 0
//! prio = 3
//! period_ms = 50
//! deadline_ms = 50          # optional, defaults to period
//! cpu_ms = 1, 1             # η_g + 1 CPU segments
//! gpu_ms = 0.5:8            # η_g segments as G^m:G^e pairs
//! par = 40                  # optional per-segment SM fraction (percent,
//!                           # 1..=100; one value per gpu_ms segment;
//!                           # must FOLLOW gpu_ms; default 100 = serial)
//! mode = suspend            # suspend | busy
//! best_effort = false
//! ```
//!
//! Round-trips: `to_text` writes the same format `parse` reads, so
//! generated tasksets can be exported, edited and re-analysed. Legacy
//! single-GPU files (no `num_gpus`/`gpu` keys) parse unchanged, and
//! `to_text` emits the multi-GPU keys only when they differ from the
//! single-GPU defaults, so legacy files round-trip byte-identically.

use crate::model::{ms, to_ms, GpuContext, GpuSegment, Platform, Task, TaskSet, WaitMode};

/// Named GPU-engine presets for the measured Jetson boards (§7.1.1 /
/// §7.2, Fig. 12–13): ε up to ~1 ms on both boards (Orin ~10% higher
/// despite half the GPU clock), θ *lower* on Orin, L = 1024 µs on both.
/// Usable as `profile = <name>` inside a `[gpu]` section (put it first;
/// later keys override individual fields), as `--board` presets in the
/// case study, and as the board axis of `gcaps exp scenarios`.
pub const GPU_PROFILES: [(&str, GpuContext); 2] = [
    ("xavier_nx", GpuContext { tsg_slice: 1024, theta: 250, epsilon: 1000 }),
    ("orin_nano", GpuContext { tsg_slice: 1024, theta: 160, epsilon: 1100 }),
];

/// Look up a named board preset.
pub fn gpu_profile(name: &str) -> Option<GpuContext> {
    GPU_PROFILES.iter().find(|(n, _)| *n == name).map(|&(_, ctx)| ctx)
}

/// Parse a taskset from the text format above.
pub fn parse(text: &str) -> Result<TaskSet, String> {
    let mut num_cpus = Platform::default().num_cpus;
    let mut base = GpuContext::default();
    let mut num_gpus: Option<usize> = None;
    let mut gpu_sections: Vec<GpuContext> = Vec::new();
    let mut tasks: Vec<Task> = Vec::new();
    let mut section = String::new();
    let mut current: Option<Task> = None;
    let mut current_gpu: Option<GpuContext> = None;
    // Whether an explicit per-field key was set in the CURRENT [gpu]
    // section — a later `profile =` would silently discard it.
    let mut current_gpu_touched = false;

    let flush = |tasks: &mut Vec<Task>,
                 gpu_sections: &mut Vec<GpuContext>,
                 current: &mut Option<Task>,
                 current_gpu: &mut Option<GpuContext>| {
        if let Some(t) = current.take() {
            tasks.push(t);
        }
        if let Some(g) = current_gpu.take() {
            gpu_sections.push(g);
        }
    };

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| format!("line {}: {msg}", lineno + 1);
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            flush(&mut tasks, &mut gpu_sections, &mut current, &mut current_gpu);
            section = name.trim().to_string();
            if section == "task" {
                let id = tasks.len();
                current = Some(Task {
                    id,
                    name: format!("task{id}"),
                    period: 0,
                    deadline: 0,
                    cpu_segments: vec![],
                    gpu_segments: vec![],
                    core: 0,
                    gpu: 0,
                    cpu_prio: 0,
                    gpu_prio: 0,
                    best_effort: false,
                    mode: WaitMode::SelfSuspend,
                });
            } else if section == "gpu" {
                // Each [gpu] section starts from the scalar defaults
                // accumulated so far and overrides per-engine.
                current_gpu = Some(base);
                current_gpu_touched = false;
            } else if section != "platform" {
                return Err(err(&format!("unknown section [{section}]")));
            }
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .map(|(k, v)| (k.trim(), v.trim()))
            .ok_or_else(|| err("expected key = value"))?;
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|_| err(&format!("bad number {v:?}")));
        match (section.as_str(), key) {
            ("platform", "num_cpus") => {
                num_cpus = value.parse().map_err(|_| err("bad num_cpus"))?;
            }
            ("platform", "num_gpus") => {
                let n: usize = value.parse().map_err(|_| err("bad num_gpus"))?;
                if n == 0 {
                    return Err(err("num_gpus must be at least 1"));
                }
                num_gpus = Some(n);
            }
            ("platform", k @ ("epsilon_us" | "theta_us" | "slice_us")) => {
                // [gpu] sections snapshot `base` when they open, so a
                // scalar override arriving afterwards would be silently
                // dropped — reject it instead.
                if !gpu_sections.is_empty() || current_gpu.is_some() {
                    return Err(err(&format!(
                        "platform {k} must precede the [gpu] sections it applies to"
                    )));
                }
                match k {
                    "epsilon_us" => {
                        base.epsilon = value.parse().map_err(|_| err("bad epsilon_us"))?
                    }
                    "theta_us" => {
                        base.theta = value.parse().map_err(|_| err("bad theta_us"))?
                    }
                    _ => base.tsg_slice = value.parse().map_err(|_| err("bad slice_us"))?,
                }
            }
            ("gpu", k) => {
                let g = current_gpu.as_mut().ok_or_else(|| err("gpu key outside [gpu]"))?;
                match k {
                    "profile" => {
                        // Whole-context preset. It replaces the entire
                        // context, so it must come FIRST in its section
                        // — a profile after an explicit key would
                        // silently discard that key; reject instead.
                        if current_gpu_touched {
                            return Err(err(
                                "profile must precede the explicit gpu keys it applies to",
                            ));
                        }
                        *g = gpu_profile(value).ok_or_else(|| {
                            err(&format!(
                                "unknown gpu profile {value:?} (known: {})",
                                GPU_PROFILES
                                    .iter()
                                    .map(|(n, _)| *n)
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            ))
                        })?;
                    }
                    "epsilon_us" => {
                        g.epsilon = value.parse().map_err(|_| err("bad epsilon_us"))?;
                        current_gpu_touched = true;
                    }
                    "theta_us" => {
                        g.theta = value.parse().map_err(|_| err("bad theta_us"))?;
                        current_gpu_touched = true;
                    }
                    "slice_us" => {
                        g.tsg_slice = value.parse().map_err(|_| err("bad slice_us"))?;
                        current_gpu_touched = true;
                    }
                    other => return Err(err(&format!("unknown gpu key {other:?}"))),
                }
            }
            ("task", k) => {
                let t = current.as_mut().ok_or_else(|| err("task key outside [task]"))?;
                match k {
                    "name" => t.name = value.to_string(),
                    "core" => t.core = value.parse().map_err(|_| err("bad core"))?,
                    "gpu" => t.gpu = value.parse().map_err(|_| err("bad gpu"))?,
                    "prio" => {
                        t.cpu_prio = value.parse().map_err(|_| err("bad prio"))?;
                        if t.gpu_prio == 0 {
                            t.gpu_prio = t.cpu_prio;
                        }
                    }
                    "gpu_prio" => {
                        t.gpu_prio = value.parse().map_err(|_| err("bad gpu_prio"))?
                    }
                    "period_ms" => t.period = ms(parse_f64(value)?),
                    "deadline_ms" => t.deadline = ms(parse_f64(value)?),
                    "cpu_ms" => {
                        t.cpu_segments = value
                            .split(',')
                            .map(|v| parse_f64(v.trim()).map(ms))
                            .collect::<Result<_, _>>()?;
                    }
                    "gpu_ms" => {
                        t.gpu_segments = value
                            .split(',')
                            .map(|seg| {
                                let (gm, ge) = seg
                                    .trim()
                                    .split_once(':')
                                    .ok_or_else(|| err("gpu_ms needs G^m:G^e pairs"))?;
                                Ok(GpuSegment::new(
                                    ms(parse_f64(gm.trim())?),
                                    ms(parse_f64(ge.trim())?),
                                ))
                            })
                            .collect::<Result<_, String>>()?;
                    }
                    "par" => {
                        // Per-segment SM fractions (RTGPU-style fine-grain
                        // parallelism). The list aligns positionally with
                        // gpu_ms, so it must FOLLOW it and match its
                        // length — anything else is a silent misalignment
                        // waiting to happen, so reject strictly.
                        if t.gpu_segments.is_empty() {
                            return Err(err("par requires a preceding gpu_ms line"));
                        }
                        let fracs: Vec<u32> = value
                            .split(',')
                            .map(|v| {
                                v.trim().parse::<u32>().map_err(|_| {
                                    err(&format!(
                                        "bad par value {:?} (integer percent expected)",
                                        v.trim()
                                    ))
                                })
                            })
                            .collect::<Result<_, _>>()?;
                        if fracs.len() != t.gpu_segments.len() {
                            return Err(err(&format!(
                                "par lists {} fractions but gpu_ms has {} segments",
                                fracs.len(),
                                t.gpu_segments.len()
                            )));
                        }
                        for (seg, p) in t.gpu_segments.iter_mut().zip(fracs) {
                            // Range (1..=100) is enforced by
                            // TaskSet::validate at end of parse.
                            seg.par = crate::model::SmFraction::new(p);
                        }
                    }
                    "mode" => {
                        t.mode = match value {
                            "suspend" => WaitMode::SelfSuspend,
                            "busy" => WaitMode::BusyWait,
                            other => return Err(err(&format!("bad mode {other:?}"))),
                        }
                    }
                    "best_effort" => {
                        t.best_effort =
                            value.parse().map_err(|_| err("bad best_effort"))?
                    }
                    other => return Err(err(&format!("unknown task key {other:?}"))),
                }
            }
            (_, k) => return Err(err(&format!("key {k:?} outside a section"))),
        }
    }
    flush(&mut tasks, &mut gpu_sections, &mut current, &mut current_gpu);
    // Defaults: deadline = period.
    for t in &mut tasks {
        if t.deadline == 0 {
            t.deadline = t.period;
        }
    }
    let gpus: Vec<GpuContext> = if gpu_sections.is_empty() {
        vec![base; num_gpus.unwrap_or(1)]
    } else {
        if let Some(n) = num_gpus {
            if n != gpu_sections.len() {
                return Err(format!(
                    "num_gpus = {n} but {} [gpu] sections given",
                    gpu_sections.len()
                ));
            }
        }
        gpu_sections
    };
    let ts = TaskSet::new(tasks, Platform { num_cpus, gpus });
    ts.validate()?;
    Ok(ts)
}

/// Render a taskset back into the text format. Single-GPU platforms
/// emit exactly the legacy (pre-multi-GPU) bytes; uniform multi-GPU
/// platforms add `num_gpus`; heterogeneous ones add `[gpu]` sections.
pub fn to_text(ts: &TaskSet) -> String {
    let gpus = &ts.platform.gpus;
    let uniform = ts.platform.is_uniform();
    let mut out = String::from("[platform]\n");
    out.push_str(&format!("num_cpus = {}\n", ts.platform.num_cpus));
    if gpus.len() != 1 {
        out.push_str(&format!("num_gpus = {}\n", gpus.len()));
    }
    if uniform {
        out.push_str(&format!("epsilon_us = {}\n", gpus[0].epsilon));
        out.push_str(&format!("theta_us = {}\n", gpus[0].theta));
        out.push_str(&format!("slice_us = {}\n", gpus[0].tsg_slice));
    } else {
        for g in gpus {
            out.push_str("\n[gpu]\n");
            out.push_str(&format!("epsilon_us = {}\n", g.epsilon));
            out.push_str(&format!("theta_us = {}\n", g.theta));
            out.push_str(&format!("slice_us = {}\n", g.tsg_slice));
        }
    }
    for t in &ts.tasks {
        out.push_str("\n[task]\n");
        out.push_str(&format!("name = {}\n", t.name));
        out.push_str(&format!("core = {}\n", t.core));
        if t.gpu != 0 {
            out.push_str(&format!("gpu = {}\n", t.gpu));
        }
        out.push_str(&format!("prio = {}\n", t.cpu_prio));
        if t.gpu_prio != t.cpu_prio {
            out.push_str(&format!("gpu_prio = {}\n", t.gpu_prio));
        }
        out.push_str(&format!("period_ms = {}\n", to_ms(t.period)));
        if t.deadline != t.period {
            out.push_str(&format!("deadline_ms = {}\n", to_ms(t.deadline)));
        }
        out.push_str(&format!(
            "cpu_ms = {}\n",
            t.cpu_segments.iter().map(|&c| to_ms(c).to_string()).collect::<Vec<_>>().join(", ")
        ));
        if !t.gpu_segments.is_empty() {
            out.push_str(&format!(
                "gpu_ms = {}\n",
                t.gpu_segments
                    .iter()
                    .map(|g| format!("{}:{}", to_ms(g.misc), to_ms(g.exec)))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
            // Emitted only when some fraction is < 100% so legacy
            // (serial) tasksets keep their exact pre-fine-grain bytes.
            if t.has_fine_grain() {
                out.push_str(&format!(
                    "par = {}\n",
                    t.gpu_segments
                        .iter()
                        .map(|g| g.par.pct().to_string())
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        if t.mode == WaitMode::BusyWait {
            out.push_str("mode = busy\n");
        }
        if t.best_effort {
            out.push_str("best_effort = true\n");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgen::{generate, GenParams};
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    const SAMPLE: &str = r#"
# a two-task system
[platform]
num_cpus = 2
epsilon_us = 500
theta_us = 100

[task]
name = camera
core = 0
prio = 2
period_ms = 50
cpu_ms = 1, 1
gpu_ms = 0.5:8

[task]
name = planner
core = 1
prio = 1
period_ms = 100
cpu_ms = 20
mode = busy
"#;

    #[test]
    fn parses_sample() {
        let ts = parse(SAMPLE).unwrap();
        assert_eq!(ts.platform.num_cpus, 2);
        assert_eq!(ts.platform.num_gpus(), 1); // default kept
        assert_eq!(ts.platform.gpus[0].epsilon, 500);
        assert_eq!(ts.platform.gpus[0].tsg_slice, 1024); // default kept
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.tasks[0].name, "camera");
        assert_eq!(ts.tasks[0].gpu_segments[0].exec, ms(8.0));
        assert_eq!(ts.tasks[0].deadline, ms(50.0)); // defaulted
        assert_eq!(ts.tasks[1].mode, WaitMode::BusyWait);
    }

    #[test]
    fn roundtrip_sample() {
        let ts = parse(SAMPLE).unwrap();
        let ts2 = parse(&to_text(&ts)).unwrap();
        assert_eq!(ts.tasks, ts2.tasks);
        assert_eq!(ts.platform, ts2.platform);
    }

    #[test]
    fn roundtrip_generated_tasksets() {
        // Satellite property (PR 2): parse ∘ to_text = id over ~100
        // generated tasksets, cycling through 1/2/4-GPU platforms (the
        // 1-GPU cases exercise the legacy format path).
        forall("config roundtrip", 102, |rng| {
            let num_gpus = [1usize, 2, 4][rng.range_usize(0, 2)];
            let p = GenParams {
                platform: crate::model::Platform::default().with_num_gpus(num_gpus),
                ..GenParams::default()
            };
            let ts = generate(rng, &p);
            let text = to_text(&ts);
            let back = parse(&text).map_err(|e| format!("parse failed: {e}\n{text}"))?;
            if back.tasks != ts.tasks {
                return Err(format!("tasks differ after roundtrip (g = {num_gpus})"));
            }
            if back.platform != ts.platform {
                return Err(format!("platform differs after roundtrip (g = {num_gpus})"));
            }
            Ok(())
        });
        let _ = Pcg32::seeded(0); // keep import used
    }

    #[test]
    fn single_gpu_text_has_no_multigpu_keys() {
        // Legacy byte-identity: a 1-GPU taskset's export must not grow
        // num_gpus/gpu keys (pre-redesign files and exports match).
        let mut rng = Pcg32::seeded(5);
        let ts = generate(&mut rng, &GenParams::default());
        let text = to_text(&ts);
        assert!(!text.contains("num_gpus"), "unexpected num_gpus key:\n{text}");
        assert!(!text.contains("[gpu]"), "unexpected [gpu] section:\n{text}");
        assert!(!text.contains("\ngpu = "), "unexpected task gpu key:\n{text}");
    }

    #[test]
    fn parses_num_gpus_and_task_assignment() {
        let text = "[platform]\nnum_cpus = 2\nnum_gpus = 2\nepsilon_us = 500\n\
                    [task]\nname=a\nprio=2\ngpu=1\nperiod_ms=10\ncpu_ms=1,1\ngpu_ms=0.5:2\n\
                    [task]\nname=b\nprio=1\nperiod_ms=10\ncpu_ms=1\n";
        let ts = parse(text).unwrap();
        assert_eq!(ts.platform.num_gpus(), 2);
        assert_eq!(ts.platform.gpus[0].epsilon, 500);
        assert_eq!(ts.platform.gpus[1].epsilon, 500);
        assert_eq!(ts.tasks[0].gpu, 1);
        assert_eq!(ts.tasks[1].gpu, 0);
    }

    #[test]
    fn heterogeneous_gpu_sections_roundtrip() {
        let text = "[platform]\nnum_cpus = 2\n\
                    [gpu]\nepsilon_us = 1000\ntheta_us = 200\n\
                    [gpu]\nepsilon_us = 400\ntheta_us = 80\nslice_us = 2048\n\
                    [task]\nname=a\nprio=1\ngpu=1\nperiod_ms=10\ncpu_ms=1,1\ngpu_ms=0.5:2\n";
        let ts = parse(text).unwrap();
        assert_eq!(ts.platform.num_gpus(), 2);
        assert_eq!(ts.platform.gpus[1].epsilon, 400);
        assert_eq!(ts.platform.gpus[1].tsg_slice, 2048);
        assert_eq!(ts.platform.gpus[0].tsg_slice, 1024);
        let back = parse(&to_text(&ts)).unwrap();
        assert_eq!(back.platform, ts.platform);
        assert_eq!(back.tasks, ts.tasks);
    }

    #[test]
    fn gpu_profiles_parse_and_override() {
        // A bare profile equals the registered preset.
        let ts = parse("[gpu]\nprofile = orin_nano\n").unwrap();
        assert_eq!(ts.platform.gpus[0], gpu_profile("orin_nano").unwrap());
        assert_eq!(ts.platform.gpus[0].theta, 160);
        // Later keys refine the preset; a second section can use another
        // board, yielding a heterogeneous platform.
        let ts = parse(
            "[gpu]\nprofile = xavier_nx\ntheta_us = 99\n\
             [gpu]\nprofile = orin_nano\n",
        )
        .unwrap();
        assert_eq!(ts.platform.num_gpus(), 2);
        assert_eq!(ts.platform.gpus[0].theta, 99);
        assert_eq!(ts.platform.gpus[0].epsilon, 1000);
        assert_eq!(ts.platform.gpus[1], gpu_profile("orin_nano").unwrap());
        assert!(!ts.platform.is_uniform());
        // Unknown profile names are an error, not a silent default.
        assert!(parse("[gpu]\nprofile = bogus_board\n").is_err());
        // A profile AFTER an explicit key would silently discard it —
        // rejected (mirrors the scalar-key-after-[gpu]-section rule).
        assert!(parse("[gpu]\nepsilon_us = 400\nprofile = xavier_nx\n").is_err());
        // ...but only within the same section: a fresh section resets.
        parse("[gpu]\nepsilon_us = 400\n[gpu]\nprofile = orin_nano\n").unwrap();
    }

    #[test]
    fn rejects_bad_multigpu_configs() {
        // gpu index out of range.
        assert!(parse(
            "[platform]\nnum_cpus = 1\n\
             [task]\nname=a\nprio=1\ngpu=1\nperiod_ms=10\ncpu_ms=1,1\ngpu_ms=0.5:2\n"
        )
        .is_err());
        // num_gpus = 0.
        assert!(parse("[platform]\nnum_gpus = 0\n").is_err());
        // num_gpus contradicting the [gpu] section count.
        assert!(parse("[platform]\nnum_gpus = 3\n[gpu]\ntheta_us = 100\n").is_err());
        // unknown key inside [gpu].
        assert!(parse("[gpu]\nbogus = 1\n").is_err());
        // scalar GPU keys after a [gpu] section would be silently
        // dropped — rejected instead.
        assert!(parse("[gpu]\nepsilon_us = 400\n[platform]\ntheta_us = 99\n").is_err());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("[task]\nprio = x\n").is_err());
        assert!(parse("[bogus]\n").is_err());
        assert!(parse("num_cpus = 2\n").is_err()); // key outside section
        assert!(parse("[task]\nname = a\ncpu_ms = 1\ngpu_ms = 5\n").is_err()); // no G^m:G^e
    }

    #[test]
    fn par_roundtrips_and_defaults_serial() {
        // Fractions survive a text round-trip; omitting `par` keeps the
        // serial default (100%) on every segment.
        let text = "[platform]\nnum_cpus = 1\n\
                    [task]\nname=a\nprio=1\nperiod_ms=10\ncpu_ms=1,1,1\n\
                    gpu_ms = 0.5:2, 0.5:1\npar = 40, 100\n";
        let ts = parse(text).unwrap();
        assert_eq!(ts.tasks[0].gpu_segments[0].par.pct(), 40);
        assert!(ts.tasks[0].gpu_segments[1].par.is_full());
        assert!(ts.has_fine_grain());
        let rendered = to_text(&ts);
        assert!(rendered.contains("par = 40, 100\n"), "missing par:\n{rendered}");
        let back = parse(&rendered).unwrap();
        assert_eq!(back.tasks, ts.tasks);
        // No `par =` key → all segments serial → no `par =` on export
        // (legacy byte-identity).
        let serial = parse(
            "[platform]\nnum_cpus = 1\n\
             [task]\nname=a\nprio=1\nperiod_ms=10\ncpu_ms=1,1\ngpu_ms=0.5:2\n",
        )
        .unwrap();
        assert!(!serial.has_fine_grain());
        assert!(!to_text(&serial).contains("par"), "serial export grew a par key");
    }

    #[test]
    fn rejects_bad_par() {
        let base = "[platform]\nnum_cpus = 1\n[task]\nname=a\nprio=1\nperiod_ms=10\n";
        // par before/without gpu_ms.
        assert!(parse(&format!("{base}cpu_ms=1,1\npar = 50\ngpu_ms=0.5:2\n")).is_err());
        assert!(parse(&format!("{base}cpu_ms=1\npar = 50\n")).is_err());
        // Length mismatch with gpu_ms.
        assert!(parse(&format!("{base}cpu_ms=1,1\ngpu_ms=0.5:2\npar = 50, 50\n")).is_err());
        // Non-integer / negative values.
        assert!(parse(&format!("{base}cpu_ms=1,1\ngpu_ms=0.5:2\npar = half\n")).is_err());
        assert!(parse(&format!("{base}cpu_ms=1,1\ngpu_ms=0.5:2\npar = -5\n")).is_err());
        // Out-of-range percents (validate's 1..=100 rule).
        assert!(parse(&format!("{base}cpu_ms=1,1\ngpu_ms=0.5:2\npar = 0\n")).is_err());
        assert!(parse(&format!("{base}cpu_ms=1,1\ngpu_ms=0.5:2\npar = 101\n")).is_err());
    }

    #[test]
    fn rejects_invalid_taskset() {
        // Duplicate priorities fail validation.
        let text = "[platform]\nnum_cpus = 1\n\
                    [task]\nname=a\nprio=1\nperiod_ms=10\ncpu_ms=1\n\
                    [task]\nname=b\nprio=1\nperiod_ms=10\ncpu_ms=1\n";
        assert!(parse(text).is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let ts = parse("# header\n\n[platform]\nnum_cpus = 3 # inline\n").unwrap();
        assert_eq!(ts.platform.num_cpus, 3);
        assert!(ts.is_empty());
    }
}
