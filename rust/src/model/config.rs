//! Plain-text taskset format: lets users analyse/simulate their own
//! systems without writing Rust (the offline crate set has no serde, so
//! this is a small hand-rolled `key=value` section format).
//!
//! ```text
//! # comments with '#'
//! [platform]
//! num_cpus = 4
//! epsilon_us = 1000
//! theta_us = 200
//! slice_us = 1024
//!
//! [task]
//! name = camera
//! core = 0
//! prio = 3
//! period_ms = 50
//! deadline_ms = 50          # optional, defaults to period
//! cpu_ms = 1, 1             # η_g + 1 CPU segments
//! gpu_ms = 0.5:8            # η_g segments as G^m:G^e pairs
//! mode = suspend            # suspend | busy
//! best_effort = false
//! ```
//!
//! Round-trips: `to_text` writes the same format `parse` reads, so
//! generated tasksets can be exported, edited and re-analysed.

use crate::model::{ms, to_ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

/// Parse a taskset from the text format above.
pub fn parse(text: &str) -> Result<TaskSet, String> {
    let mut platform = Platform::default();
    let mut tasks: Vec<Task> = Vec::new();
    let mut section = String::new();
    let mut current: Option<Task> = None;

    let flush = |tasks: &mut Vec<Task>, current: &mut Option<Task>| {
        if let Some(t) = current.take() {
            tasks.push(t);
        }
    };

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| format!("line {}: {msg}", lineno + 1);
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            if section == "task" {
                flush(&mut tasks, &mut current);
            }
            section = name.trim().to_string();
            if section == "task" {
                let id = tasks.len();
                current = Some(Task {
                    id,
                    name: format!("task{id}"),
                    period: 0,
                    deadline: 0,
                    cpu_segments: vec![],
                    gpu_segments: vec![],
                    core: 0,
                    cpu_prio: 0,
                    gpu_prio: 0,
                    best_effort: false,
                    mode: WaitMode::SelfSuspend,
                });
            } else if section != "platform" {
                return Err(err(&format!("unknown section [{section}]")));
            }
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .map(|(k, v)| (k.trim(), v.trim()))
            .ok_or_else(|| err("expected key = value"))?;
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|_| err(&format!("bad number {v:?}")));
        match (section.as_str(), key) {
            ("platform", "num_cpus") => {
                platform.num_cpus =
                    value.parse().map_err(|_| err("bad num_cpus"))?;
            }
            ("platform", "epsilon_us") => {
                platform.epsilon = value.parse().map_err(|_| err("bad epsilon_us"))?;
            }
            ("platform", "theta_us") => {
                platform.theta = value.parse().map_err(|_| err("bad theta_us"))?;
            }
            ("platform", "slice_us") => {
                platform.tsg_slice = value.parse().map_err(|_| err("bad slice_us"))?;
            }
            ("task", k) => {
                let t = current.as_mut().ok_or_else(|| err("task key outside [task]"))?;
                match k {
                    "name" => t.name = value.to_string(),
                    "core" => t.core = value.parse().map_err(|_| err("bad core"))?,
                    "prio" => {
                        t.cpu_prio = value.parse().map_err(|_| err("bad prio"))?;
                        if t.gpu_prio == 0 {
                            t.gpu_prio = t.cpu_prio;
                        }
                    }
                    "gpu_prio" => {
                        t.gpu_prio = value.parse().map_err(|_| err("bad gpu_prio"))?
                    }
                    "period_ms" => t.period = ms(parse_f64(value)?),
                    "deadline_ms" => t.deadline = ms(parse_f64(value)?),
                    "cpu_ms" => {
                        t.cpu_segments = value
                            .split(',')
                            .map(|v| parse_f64(v.trim()).map(ms))
                            .collect::<Result<_, _>>()?;
                    }
                    "gpu_ms" => {
                        t.gpu_segments = value
                            .split(',')
                            .map(|seg| {
                                let (gm, ge) = seg
                                    .trim()
                                    .split_once(':')
                                    .ok_or_else(|| err("gpu_ms needs G^m:G^e pairs"))?;
                                Ok(GpuSegment::new(
                                    ms(parse_f64(gm.trim())?),
                                    ms(parse_f64(ge.trim())?),
                                ))
                            })
                            .collect::<Result<_, String>>()?;
                    }
                    "mode" => {
                        t.mode = match value {
                            "suspend" => WaitMode::SelfSuspend,
                            "busy" => WaitMode::BusyWait,
                            other => return Err(err(&format!("bad mode {other:?}"))),
                        }
                    }
                    "best_effort" => {
                        t.best_effort =
                            value.parse().map_err(|_| err("bad best_effort"))?
                    }
                    other => return Err(err(&format!("unknown task key {other:?}"))),
                }
            }
            (_, k) => return Err(err(&format!("key {k:?} outside a section"))),
        }
    }
    if section == "task" {
        flush(&mut tasks, &mut current);
    }
    // Defaults: deadline = period.
    for t in &mut tasks {
        if t.deadline == 0 {
            t.deadline = t.period;
        }
    }
    let ts = TaskSet::new(tasks, platform);
    ts.validate()?;
    Ok(ts)
}

/// Render a taskset back into the text format.
pub fn to_text(ts: &TaskSet) -> String {
    let mut out = String::from("[platform]\n");
    out.push_str(&format!("num_cpus = {}\n", ts.platform.num_cpus));
    out.push_str(&format!("epsilon_us = {}\n", ts.platform.epsilon));
    out.push_str(&format!("theta_us = {}\n", ts.platform.theta));
    out.push_str(&format!("slice_us = {}\n", ts.platform.tsg_slice));
    for t in &ts.tasks {
        out.push_str("\n[task]\n");
        out.push_str(&format!("name = {}\n", t.name));
        out.push_str(&format!("core = {}\n", t.core));
        out.push_str(&format!("prio = {}\n", t.cpu_prio));
        if t.gpu_prio != t.cpu_prio {
            out.push_str(&format!("gpu_prio = {}\n", t.gpu_prio));
        }
        out.push_str(&format!("period_ms = {}\n", to_ms(t.period)));
        if t.deadline != t.period {
            out.push_str(&format!("deadline_ms = {}\n", to_ms(t.deadline)));
        }
        out.push_str(&format!(
            "cpu_ms = {}\n",
            t.cpu_segments.iter().map(|&c| to_ms(c).to_string()).collect::<Vec<_>>().join(", ")
        ));
        if !t.gpu_segments.is_empty() {
            out.push_str(&format!(
                "gpu_ms = {}\n",
                t.gpu_segments
                    .iter()
                    .map(|g| format!("{}:{}", to_ms(g.misc), to_ms(g.exec)))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        if t.mode == WaitMode::BusyWait {
            out.push_str("mode = busy\n");
        }
        if t.best_effort {
            out.push_str("best_effort = true\n");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgen::{generate, GenParams};
    use crate::util::check::forall;
    use crate::util::rng::Pcg32;

    const SAMPLE: &str = r#"
# a two-task system
[platform]
num_cpus = 2
epsilon_us = 500
theta_us = 100

[task]
name = camera
core = 0
prio = 2
period_ms = 50
cpu_ms = 1, 1
gpu_ms = 0.5:8

[task]
name = planner
core = 1
prio = 1
period_ms = 100
cpu_ms = 20
mode = busy
"#;

    #[test]
    fn parses_sample() {
        let ts = parse(SAMPLE).unwrap();
        assert_eq!(ts.platform.num_cpus, 2);
        assert_eq!(ts.platform.epsilon, 500);
        assert_eq!(ts.platform.tsg_slice, 1024); // default kept
        assert_eq!(ts.len(), 2);
        assert_eq!(ts.tasks[0].name, "camera");
        assert_eq!(ts.tasks[0].gpu_segments[0].exec, ms(8.0));
        assert_eq!(ts.tasks[0].deadline, ms(50.0)); // defaulted
        assert_eq!(ts.tasks[1].mode, WaitMode::BusyWait);
    }

    #[test]
    fn roundtrip_sample() {
        let ts = parse(SAMPLE).unwrap();
        let ts2 = parse(&to_text(&ts)).unwrap();
        assert_eq!(ts.tasks, ts2.tasks);
        assert_eq!(ts.platform, ts2.platform);
    }

    #[test]
    fn roundtrip_generated_tasksets() {
        forall("config roundtrip", 50, |rng| {
            let ts = generate(rng, &GenParams::default());
            let text = to_text(&ts);
            let back = parse(&text).map_err(|e| format!("parse failed: {e}\n{text}"))?;
            if back.tasks != ts.tasks {
                return Err("tasks differ after roundtrip".into());
            }
            if back.platform != ts.platform {
                return Err("platform differs after roundtrip".into());
            }
            Ok(())
        });
        let _ = Pcg32::seeded(0); // keep import used
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("[task]\nprio = x\n").is_err());
        assert!(parse("[bogus]\n").is_err());
        assert!(parse("num_cpus = 2\n").is_err()); // key outside section
        assert!(parse("[task]\nname = a\ncpu_ms = 1\ngpu_ms = 5\n").is_err()); // no G^m:G^e
    }

    #[test]
    fn rejects_invalid_taskset() {
        // Duplicate priorities fail validation.
        let text = "[platform]\nnum_cpus = 1\n\
                    [task]\nname=a\nprio=1\nperiod_ms=10\ncpu_ms=1\n\
                    [task]\nname=b\nprio=1\nperiod_ms=10\ncpu_ms=1\n";
        assert!(parse(text).is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let ts = parse("# header\n\n[platform]\nnum_cpus = 3 # inline\n").unwrap();
        assert_eq!(ts.platform.num_cpus, 3);
        assert!(ts.is_empty());
    }
}
