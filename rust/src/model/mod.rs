//! Task and platform model (paper §4): sporadic tasks with alternating
//! CPU/GPU segments, partitioned fixed-priority CPUs, one shared GPU.

pub mod config;
pub mod task;
pub mod taskset;

pub use task::{ms, to_ms, GpuSegment, Task, Time, WaitMode};
pub use taskset::{Platform, TaskSet};
