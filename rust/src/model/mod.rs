//! Task and platform model (paper §4): sporadic tasks with alternating
//! CPU/GPU segments, partitioned fixed-priority CPUs, and one or more
//! GPU context queues (g = 1 reproduces the paper's platform).

pub mod config;
pub mod fault;
pub mod task;
pub mod taskset;

pub use fault::{AdaptivePolicy, DeadlineMissAction, Fault, FaultPlan};
pub use task::{ms, to_ms, GpuSegment, SmFraction, Task, Time, WaitMode};
pub use taskset::{GpuContext, Platform, TaskSet};
