//! Fault injection and overload-response model (ROADMAP "overload &
//! adaptive-policy" family): deterministic, seed-free fault *plans*
//! applied by the DES engines, per-task deadline-miss actions (cf.
//! Exo-OS `DeadlineMissAction`), and the windowed-miss-ratio policy
//! switch behind the hybrid RR↔EDF adaptive mode (cf. scx_gamer).
//!
//! Everything here is plain data: a [`FaultPlan`] names the exact
//! (task, job) pairs it perturbs, so two runs with the same plan are
//! bit-identical regardless of worker count — the same determinism
//! contract every sweep in this crate is pinned to.

use crate::model::task::{ms, Time};
use crate::model::TaskSet;

/// What the engine does the instant a job is observed past its
/// absolute deadline (checked at every settle round, so the reaction
/// lands at the first event timestamp ≥ the deadline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeadlineMissAction {
    /// Count the miss (in `deadline_misses` at completion) and keep
    /// running — the behavior of every PR before this one.
    #[default]
    Log,
    /// Keep running, but boost the job: it preempts everything on its
    /// core and ranks first for its GPU engine until it completes.
    Boost,
    /// Abort the running job immediately (partial work is discarded;
    /// the job counts in `aborted`, not `jobs`) and start the next
    /// backlogged release, if any.
    AbortJob,
    /// Abort the job *and* drop the task: future releases are
    /// discarded until a mode change re-enables it.
    DropTask,
}

impl DeadlineMissAction {
    pub fn label(&self) -> &'static str {
        match self {
            DeadlineMissAction::Log => "log",
            DeadlineMissAction::Boost => "boost",
            DeadlineMissAction::AbortJob => "abort",
            DeadlineMissAction::DropTask => "drop",
        }
    }

    pub fn from_label(s: &str) -> Option<DeadlineMissAction> {
        match s {
            "log" => Some(DeadlineMissAction::Log),
            "boost" => Some(DeadlineMissAction::Boost),
            "abort" => Some(DeadlineMissAction::AbortJob),
            "drop" => Some(DeadlineMissAction::DropTask),
            _ => None,
        }
    }

    pub const ALL: [DeadlineMissAction; 4] = [
        DeadlineMissAction::Log,
        DeadlineMissAction::Boost,
        DeadlineMissAction::AbortJob,
        DeadlineMissAction::DropTask,
    ];
}

/// One injected fault. Job indices are 0-based per task (the k-th
/// release since t = 0, counting every release — including backlogged
/// and dropped ones).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Scale job `job` of task `task`: every CPU segment runs at
    /// `cpu_pct`% of its nominal C, every GPU segment's G^e at
    /// `gpu_pct`% (G^m is CPU-side launch work and stays nominal).
    /// 100 means unchanged; 200 doubles the demand.
    WcetOverrun { task: usize, job: u64, cpu_pct: u32, gpu_pct: u32 },
    /// GPU segment `seg` of job `job` never completes: the engine runs
    /// it until the hang timeout elapses, then detects and aborts the
    /// job (counted in `hangs` and `aborted`).
    GpuHang { task: usize, job: u64, seg: usize },
    /// Taskset hot-swap at time `at`: tasks in `disable` stop (their
    /// in-flight job is aborted, future releases are dropped), tasks
    /// in `enable` resume at their next periodic release.
    ModeChange { at: Time, disable: Vec<usize>, enable: Vec<usize> },
}

/// A deterministic schedule of faults plus the hang-detection bound.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// How long a hung GPU segment occupies its engine before the
    /// watchdog aborts the job (the live-path analog is the
    /// `launch_bounded` timeout in `coordinator/gpu_server.rs`).
    pub hang_timeout: Time,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { faults: Vec::new(), hang_timeout: ms(10.0) }
    }
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// The (cpu_pct, gpu_pct) scaling for job `job` of task `task`
    /// ((100, 100) when unperturbed; the last matching fault wins).
    pub fn overrun(&self, task: usize, job: u64) -> (u32, u32) {
        let mut out = (100, 100);
        for f in &self.faults {
            if let Fault::WcetOverrun { task: t, job: j, cpu_pct, gpu_pct } = f {
                if *t == task && *j == job {
                    out = (*cpu_pct, *gpu_pct);
                }
            }
        }
        out
    }

    /// The hung GPU segment of job `job` of task `task`, if any.
    pub fn hang(&self, task: usize, job: u64) -> Option<usize> {
        let mut out = None;
        for f in &self.faults {
            if let Fault::GpuHang { task: t, job: j, seg } = f {
                if *t == task && *j == job {
                    out = Some(*seg);
                }
            }
        }
        out
    }

    /// A utilization-ramp plan: scale every job of every task whose
    /// release falls in `[start, end)` by (`cpu_pct`, `gpu_pct`).
    /// Assumes zero release offsets (release k of task i is at
    /// `k * period` — the default for all scenario sweeps).
    pub fn ramp(ts: &TaskSet, start: Time, end: Time, cpu_pct: u32, gpu_pct: u32) -> FaultPlan {
        let mut plan = FaultPlan::default();
        for t in &ts.tasks {
            if t.period == 0 {
                continue;
            }
            let first = start.div_ceil(t.period);
            let mut k = first;
            while k.saturating_mul(t.period) < end {
                plan.faults.push(Fault::WcetOverrun {
                    task: t.id,
                    job: k,
                    cpu_pct,
                    gpu_pct,
                });
                k += 1;
            }
        }
        plan
    }
}

/// Scale a duration by an integer percentage without overflow
/// (saturating at `Time::MAX`); `pct == 100` is an exact identity.
pub fn scale(t: Time, pct: u32) -> Time {
    if pct == 100 {
        return t;
    }
    ((t as u128 * pct as u128) / 100).min(Time::MAX as u128) as Time
}

/// Load-adaptive policy switching: the engine starts under its
/// configured policy and flips RR→EDF when the windowed RT miss ratio
/// crosses `up_pct`%, back when it falls to `down_pct`% (hysteresis
/// requires `down_pct < up_pct` to avoid flapping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptivePolicy {
    /// Sliding-window length (µs) over job completions/aborts.
    pub window: Time,
    /// Switch RR→EDF when `misses * 100 >= up_pct * jobs` in window.
    pub up_pct: u32,
    /// Switch EDF→RR when `misses * 100 <= down_pct * jobs` (or the
    /// window empties).
    pub down_pct: u32,
    /// Minimum windowed jobs before either switch fires.
    pub min_jobs: u64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy { window: ms(200.0), up_pct: 10, down_pct: 2, min_jobs: 5 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GpuSegment, Platform, Task, WaitMode};

    #[test]
    fn overrun_defaults_to_identity() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert_eq!(plan.overrun(3, 7), (100, 100));
        assert_eq!(plan.hang(3, 7), None);
    }

    #[test]
    fn last_matching_fault_wins() {
        let plan = FaultPlan {
            faults: vec![
                Fault::WcetOverrun { task: 1, job: 2, cpu_pct: 150, gpu_pct: 100 },
                Fault::WcetOverrun { task: 1, job: 2, cpu_pct: 300, gpu_pct: 200 },
            ],
            ..Default::default()
        };
        assert_eq!(plan.overrun(1, 2), (300, 200));
        assert_eq!(plan.overrun(1, 3), (100, 100));
        assert_eq!(plan.overrun(0, 2), (100, 100));
    }

    #[test]
    fn scale_is_exact_at_100_and_saturates() {
        assert_eq!(scale(12345, 100), 12345);
        assert_eq!(scale(1000, 150), 1500);
        assert_eq!(scale(1000, 50), 500);
        assert_eq!(scale(Time::MAX, 100), Time::MAX);
        assert_eq!(scale(Time::MAX, 300), Time::MAX);
        assert_eq!(scale(0, 300), 0);
    }

    #[test]
    fn ramp_covers_releases_in_window() {
        let t = Task {
            id: 0,
            name: "a".into(),
            period: ms(10.0),
            deadline: ms(10.0),
            cpu_segments: vec![ms(1.0)],
            gpu_segments: vec![GpuSegment::new(ms(0.1), ms(1.0))],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![t], Platform::default());
        let plan = FaultPlan::ramp(&ts, ms(25.0), ms(55.0), 200, 150);
        // Releases at 30, 40, 50 ms → jobs 3, 4, 5.
        let jobs: Vec<u64> = plan
            .faults
            .iter()
            .map(|f| match f {
                Fault::WcetOverrun { job, .. } => *job,
                _ => panic!("unexpected fault kind"),
            })
            .collect();
        assert_eq!(jobs, vec![3, 4, 5]);
        assert_eq!(plan.overrun(0, 4), (200, 150));
        assert_eq!(plan.overrun(0, 2), (100, 100));
    }

    #[test]
    fn miss_action_labels_roundtrip() {
        for a in DeadlineMissAction::ALL {
            assert_eq!(DeadlineMissAction::from_label(a.label()), Some(a));
        }
        assert_eq!(DeadlineMissAction::from_label("bogus"), None);
        assert_eq!(DeadlineMissAction::default(), DeadlineMissAction::Log);
    }
}
