//! Tasksets: collections of tasks on a multi-core platform with one or
//! more GPU context queues, with the priority/affinity accessors the
//! analysis needs (hp, hpp, per-engine sharing sets).

use super::task::{Task, Time};

/// Scheduling/overhead parameters of ONE GPU engine (context queue).
/// The paper models a single engine (§2, §5, Table 3); platforms with
/// several engines carry one `GpuContext` per engine, each with its own
/// runlist, TSG ring and driver lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GpuContext {
    /// L: TSG time-slice length of the default driver (µs); 1024 µs
    /// in the Tegra driver (§7.1.1).
    pub tsg_slice: Time,
    /// θ: GPU context-switch overhead (µs); Table 3 uses 200 µs.
    pub theta: Time,
    /// ε = α + θ: runlist update delay of GCAPS (µs); Table 3 uses 1 ms.
    pub epsilon: Time,
}

impl Default for GpuContext {
    fn default() -> GpuContext {
        GpuContext { tsg_slice: 1024, theta: 200, epsilon: 1000 }
    }
}

/// Scheduling/overhead parameters of the platform (paper §2, §5,
/// Table 3), generalized to g ≥ 1 GPU engines. Tasks are statically
/// assigned to one engine (`Task::gpu`); engines never share work, so
/// GPU blocking/interference only couples tasks on the same engine.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    /// ω: number of identical CPU cores.
    pub num_cpus: usize,
    /// The GPU engines; `gpus.len()` ≥ 1. Index = engine id.
    pub gpus: Vec<GpuContext>,
}

impl Default for Platform {
    fn default() -> Platform {
        Platform { num_cpus: 4, gpus: vec![GpuContext::default()] }
    }
}

impl Platform {
    /// The paper's platform: one GPU engine with the given overheads.
    pub fn single(num_cpus: usize, tsg_slice: Time, theta: Time, epsilon: Time) -> Platform {
        Platform { num_cpus, gpus: vec![GpuContext { tsg_slice, theta, epsilon }] }
    }

    /// A platform with `num_gpus` identical engines.
    pub fn uniform(num_cpus: usize, num_gpus: usize, ctx: GpuContext) -> Platform {
        assert!(num_gpus >= 1, "a platform needs at least one GPU engine");
        Platform { num_cpus, gpus: vec![ctx; num_gpus] }
    }

    /// A platform with explicit per-engine contexts (heterogeneous —
    /// e.g. one fast + one slow engine with different ε/θ/L).
    pub fn heterogeneous(num_cpus: usize, gpus: Vec<GpuContext>) -> Platform {
        assert!(!gpus.is_empty(), "a platform needs at least one GPU engine");
        Platform { num_cpus, gpus }
    }

    /// g: the number of GPU engines.
    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// True iff every engine carries the same ε/θ/L. Single-GPU
    /// platforms are trivially uniform.
    pub fn is_uniform(&self) -> bool {
        self.gpus.windows(2).all(|w| w[0] == w[1])
    }

    /// Resize to `num_gpus` engines, replicating engine 0's parameters.
    ///
    /// Only valid on a **uniform** platform (where replication cannot
    /// lose information); resizing a heterogeneous platform would
    /// silently discard the per-engine configuration, so it panics —
    /// use [`Platform::heterogeneous`] / [`Platform::with_gpu`] to
    /// restructure engine sets explicitly. A same-size call is a no-op
    /// and allowed on any platform.
    pub fn with_num_gpus(mut self, num_gpus: usize) -> Platform {
        assert!(num_gpus >= 1, "a platform needs at least one GPU engine");
        assert!(
            num_gpus == self.gpus.len() || self.is_uniform(),
            "with_num_gpus({num_gpus}) would discard a heterogeneous engine \
             configuration ({} distinct engines); use heterogeneous()/with_gpu()",
            self.gpus.len()
        );
        let proto = self.gpus[0];
        self.gpus.resize(num_gpus, proto);
        self
    }

    /// Replace engine `idx`'s context (builder for heterogeneous
    /// platforms; panics if `idx` is out of range).
    pub fn with_gpu(mut self, idx: usize, ctx: GpuContext) -> Platform {
        assert!(
            idx < self.gpus.len(),
            "engine index {idx} out of range ({} engines)",
            self.gpus.len()
        );
        self.gpus[idx] = ctx;
        self
    }

    /// Set ε on every engine (builder for sweeps and tests).
    pub fn with_epsilon(mut self, epsilon: Time) -> Platform {
        for g in &mut self.gpus {
            g.epsilon = epsilon;
        }
        self
    }

    /// Set θ on every engine.
    pub fn with_theta(mut self, theta: Time) -> Platform {
        for g in &mut self.gpus {
            g.theta = theta;
        }
        self
    }

    /// Set the TSG slice length on every engine.
    pub fn with_slice(mut self, tsg_slice: Time) -> Platform {
        for g in &mut self.gpus {
            g.tsg_slice = tsg_slice;
        }
        self
    }
}

/// A complete taskset plus platform parameters.
#[derive(Debug, Clone)]
pub struct TaskSet {
    pub tasks: Vec<Task>,
    pub platform: Platform,
}

impl TaskSet {
    pub fn new(tasks: Vec<Task>, platform: Platform) -> TaskSet {
        TaskSet { tasks, platform }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Number of GPU-using tasks (n^g).
    pub fn num_gpu_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.uses_gpu()).count()
    }

    /// Whether any task declares a fine-grain SM fraction below 100%.
    /// This is the master switch for the co-running DES paths: all-100%
    /// tasksets must take the exact serial legacy code path.
    pub fn has_fine_grain(&self) -> bool {
        self.tasks.iter().any(|t| t.has_fine_grain())
    }

    /// The GPU engine task `i` is assigned to.
    pub fn gpu_ctx(&self, i: usize) -> &GpuContext {
        &self.platform.gpus[self.tasks[i].gpu]
    }

    /// GPU-using tasks assigned to engine `g`.
    pub fn on_gpu(&self, g: usize) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter(move |t| t.uses_gpu() && t.gpu == g)
    }

    /// GPU-using tasks sharing τ_i's engine, excluding τ_i itself — the
    /// set whose contexts can interleave with / preempt τ_i's on the
    /// device (tasks on other engines never touch τ_i's runlist).
    pub fn sharing_gpu(&self, i: usize) -> impl Iterator<Item = &Task> {
        let me = &self.tasks[i];
        let (gpu, id) = (me.gpu, me.id);
        self.tasks.iter().filter(move |t| t.id != id && t.uses_gpu() && t.gpu == gpu)
    }

    /// Real-time tasks only (analysis targets).
    pub fn rt_tasks(&self) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter(|t| !t.best_effort)
    }

    /// Best-effort tasks (no rt_priority; GCAPS runs them time-shared
    /// only when no RT task wants the GPU).
    pub fn be_tasks(&self) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter(|t| t.best_effort)
    }

    /// hpp(τ_i): higher-priority RT tasks on the SAME core as τ_i
    /// (by CPU priority).
    pub fn hpp(&self, i: usize) -> impl Iterator<Item = &Task> {
        let me = &self.tasks[i];
        let (core, prio, id) = (me.core, me.cpu_prio, me.id);
        self.tasks
            .iter()
            .filter(move |t| !t.best_effort && t.id != id && t.core == core && t.cpu_prio > prio)
    }

    /// hp(τ_i) \ hpp(τ_i): higher-priority RT tasks on DIFFERENT cores,
    /// ordered by CPU priority (the default when π^g = π^c).
    pub fn hp_other_core(&self, i: usize) -> impl Iterator<Item = &Task> {
        let me = &self.tasks[i];
        let (core, prio, id) = (me.core, me.cpu_prio, me.id);
        self.tasks
            .iter()
            .filter(move |t| !t.best_effort && t.id != id && t.core != core && t.cpu_prio > prio)
    }

    /// Same as `hp_other_core` but ordered by GPU priority (π^g), used
    /// when the §5.3 separate GPU priority assignment is active. For a
    /// CPU-only τ_i, its "GPU priority" is taken as `gpu_prio` too (set
    /// equal to its CPU priority by the generator), which preserves the
    /// paper's per-core order constraint.
    pub fn hp_gpu_other_core(&self, i: usize) -> impl Iterator<Item = &Task> {
        let me = &self.tasks[i];
        let (core, prio, id) = (me.core, me.gpu_prio, me.id);
        self.tasks
            .iter()
            .filter(move |t| !t.best_effort && t.id != id && t.core != core && t.gpu_prio > prio)
    }

    /// Lower-priority RT tasks (by CPU priority) — for lock-based blocking.
    pub fn lp(&self, i: usize) -> impl Iterator<Item = &Task> {
        let me = &self.tasks[i];
        let (prio, id) = (me.cpu_prio, me.id);
        self.tasks.iter().filter(move |t| !t.best_effort && t.id != id && t.cpu_prio < prio)
    }

    /// Tasks on a given core.
    pub fn on_core(&self, core: usize) -> impl Iterator<Item = &Task> {
        self.tasks.iter().filter(move |t| t.core == core)
    }

    /// Total utilization of a core.
    pub fn core_utilization(&self, core: usize) -> f64 {
        self.on_core(core).map(|t| t.utilization()).sum()
    }

    /// Validate the whole set: per-task structure, core/GPU bounds,
    /// unique RT CPU priorities, per-core GPU/CPU priority order
    /// coherence (§5.3 deadlock-avoidance constraint).
    pub fn validate(&self) -> Result<(), String> {
        if self.platform.gpus.is_empty() {
            return Err("platform has no GPU engines".into());
        }
        for t in &self.tasks {
            t.validate()?;
            if t.core >= self.platform.num_cpus {
                return Err(format!(
                    "task {}: core {} out of range (num_cpus = {})",
                    t.id, t.core, self.platform.num_cpus
                ));
            }
            if t.gpu >= self.platform.num_gpus() {
                return Err(format!(
                    "task {}: gpu {} out of range (num_gpus = {})",
                    t.id,
                    t.gpu,
                    self.platform.num_gpus()
                ));
            }
        }
        // ids must equal indices (the analysis relies on it).
        for (idx, t) in self.tasks.iter().enumerate() {
            if t.id != idx {
                return Err(format!("task at index {idx} has id {}", t.id));
            }
        }
        let mut prios: Vec<u32> =
            self.rt_tasks().map(|t| t.cpu_prio).collect();
        prios.sort_unstable();
        prios.dedup();
        if prios.len() != self.rt_tasks().count() {
            return Err("duplicate RT CPU priorities".into());
        }
        // §5.3: same-core relative GPU priority order must match CPU order
        // (only meaningful between GPU-using tasks sharing an engine —
        // CPU-only tasks never wait for a GPU, and tasks on different
        // engines never wait in the same context queue, so no deadlock
        // channel exists through them).
        for a in self.rt_tasks().filter(|t| t.uses_gpu()) {
            for b in self.rt_tasks().filter(|t| t.uses_gpu()) {
                if a.id != b.id && a.core == b.core && a.gpu == b.gpu && a.cpu_prio > b.cpu_prio {
                    if a.gpu_prio <= b.gpu_prio {
                        return Err(format!(
                            "tasks {} and {} on core {} / gpu {}: GPU priority order \
                             violates CPU order (deadlock risk, §5.3)",
                            a.id, b.id, a.core, a.gpu
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::task::{ms, GpuSegment, WaitMode};

    fn simple_set() -> TaskSet {
        let mk_gpu = |id: usize, core: usize, prio: u32| Task {
            id,
            name: format!("t{id}"),
            period: ms(100.0),
            deadline: ms(100.0),
            cpu_segments: vec![ms(1.0), ms(1.0)],
            gpu_segments: vec![GpuSegment::new(ms(1.0), ms(5.0))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let tasks = vec![
            mk_gpu(0, 0, 30),
            Task::cpu_only(1, 0, 20, ms(10.0), ms(100.0)),
            mk_gpu(2, 1, 10),
        ];
        TaskSet::new(tasks, Platform::default())
    }

    #[test]
    fn validates() {
        simple_set().validate().unwrap();
    }

    #[test]
    fn hpp_same_core_only() {
        let ts = simple_set();
        let hpp: Vec<usize> = ts.hpp(1).map(|t| t.id).collect();
        assert_eq!(hpp, vec![0]);
        assert_eq!(ts.hpp(0).count(), 0);
    }

    #[test]
    fn hp_other_core() {
        let ts = simple_set();
        let hp: Vec<usize> = ts.hp_other_core(2).map(|t| t.id).collect();
        assert_eq!(hp, vec![0, 1]);
    }

    #[test]
    fn gpu_task_count() {
        assert_eq!(simple_set().num_gpu_tasks(), 2);
    }

    #[test]
    fn duplicate_priorities_rejected() {
        let mut ts = simple_set();
        ts.tasks[1].cpu_prio = 30;
        assert!(ts.validate().is_err());
    }

    #[test]
    fn core_out_of_range_rejected() {
        let mut ts = simple_set();
        ts.tasks[0].core = 9;
        assert!(ts.validate().is_err());
    }

    #[test]
    fn gpu_priority_order_constraint() {
        let mut ts = simple_set();
        // Put both GPU-using tasks (0 and 2) on core 0, then invert
        // their GPU priority order relative to CPU order (30 > 10).
        ts.tasks[2].core = 0;
        ts.tasks[0].gpu_prio = 5;
        ts.tasks[2].gpu_prio = 6;
        assert!(ts.validate().is_err());
    }

    #[test]
    fn gpu_priority_order_ignores_cpu_only_tasks() {
        let mut ts = simple_set();
        // Task 1 is CPU-only: inverting its gpu_prio vs task 0 is fine.
        ts.tasks[0].gpu_prio = 5;
        ts.tasks[1].gpu_prio = 6;
        ts.validate().unwrap();
    }

    #[test]
    fn best_effort_excluded_from_rt_queries() {
        let mut ts = simple_set();
        ts.tasks[0].best_effort = true;
        assert_eq!(ts.rt_tasks().count(), 2);
        assert_eq!(ts.hpp(1).count(), 0); // BE task no longer interferes via hpp
    }

    #[test]
    fn core_utilization_sums() {
        let ts = simple_set();
        let u0 = ts.core_utilization(0);
        // task 0: C = 2 ms, G = 1 + 5 = 6 ms, T = 100 ms; task 1: 10/100
        assert!((u0 - (8.0 / 100.0 + 10.0 / 100.0)).abs() < 1e-9);
    }

    #[test]
    fn gpu_out_of_range_rejected() {
        let mut ts = simple_set();
        ts.tasks[0].gpu = 1; // platform has a single engine
        assert!(ts.validate().is_err());
        ts.platform = ts.platform.with_num_gpus(2);
        ts.validate().unwrap();
    }

    #[test]
    fn gpu_priority_order_ignores_cross_engine_pairs() {
        // Same core, inverted GPU priorities — but different engines, so
        // no shared context queue and no §5.3 deadlock channel.
        let mut ts = simple_set();
        ts.platform = ts.platform.with_num_gpus(2);
        ts.tasks[2].core = 0;
        ts.tasks[2].gpu = 1;
        ts.tasks[0].gpu_prio = 5;
        ts.tasks[2].gpu_prio = 6;
        ts.validate().unwrap();
        // Collapsing them onto one engine re-arms the constraint.
        ts.tasks[2].gpu = 0;
        assert!(ts.validate().is_err());
    }

    #[test]
    fn sharing_gpu_filters_by_engine() {
        let mut ts = simple_set();
        ts.platform = ts.platform.with_num_gpus(2);
        ts.tasks[2].gpu = 1;
        // Tasks 0 and 2 are the GPU users; on different engines they no
        // longer share.
        assert_eq!(ts.sharing_gpu(0).count(), 0);
        assert_eq!(ts.on_gpu(0).count(), 1);
        assert_eq!(ts.on_gpu(1).count(), 1);
        ts.tasks[2].gpu = 0;
        let ids: Vec<usize> = ts.sharing_gpu(0).map(|t| t.id).collect();
        assert_eq!(ids, vec![2]);
    }

    #[test]
    fn platform_builders() {
        let p = Platform::single(2, 1024, 200, 1000);
        assert_eq!(p, Platform { num_cpus: 2, ..Platform::default() });
        let p2 = p.clone().with_num_gpus(3).with_epsilon(500).with_theta(100).with_slice(2048);
        assert_eq!(p2.num_gpus(), 3);
        for g in &p2.gpus {
            assert_eq!((g.epsilon, g.theta, g.tsg_slice), (500, 100, 2048));
        }
        let u = Platform::uniform(4, 2, GpuContext::default());
        assert_eq!(u.num_gpus(), 2);
        assert_eq!(u.gpus[0], u.gpus[1]);
    }

    #[test]
    fn heterogeneous_builders() {
        let fast = GpuContext { tsg_slice: 1024, theta: 50, epsilon: 250 };
        let slow = GpuContext { tsg_slice: 2048, theta: 400, epsilon: 2000 };
        let h = Platform::heterogeneous(3, vec![fast, slow]);
        assert_eq!((h.num_cpus, h.num_gpus()), (3, 2));
        assert!(!h.is_uniform());
        assert_eq!((h.gpus[0], h.gpus[1]), (fast, slow));

        let p = Platform::default().with_num_gpus(2).with_gpu(1, slow);
        assert!(!p.is_uniform());
        assert_eq!(p.gpus[0], GpuContext::default());
        assert_eq!(p.gpus[1], slow);

        // Uniformity: trivially true at g = 1 and after replication.
        assert!(Platform::default().is_uniform());
        assert!(Platform::default().with_num_gpus(4).is_uniform());
        assert!(Platform::uniform(4, 3, slow).is_uniform());
        // Overwriting every engine to the same context restores it.
        assert!(p.with_gpu(1, GpuContext::default()).is_uniform());
    }

    #[test]
    fn with_num_gpus_same_size_is_a_noop_on_heterogeneous_platforms() {
        let h = Platform::heterogeneous(
            2,
            vec![GpuContext::default(), GpuContext { epsilon: 400, ..GpuContext::default() }],
        );
        let same = h.clone().with_num_gpus(2);
        assert_eq!(same, h);
    }

    #[test]
    #[should_panic(expected = "heterogeneous")]
    fn with_num_gpus_refuses_to_discard_heterogeneous_engines() {
        // Regression: this used to silently replicate engine 0, throwing
        // away the per-engine configuration.
        let h = Platform::heterogeneous(
            2,
            vec![GpuContext::default(), GpuContext { epsilon: 400, ..GpuContext::default() }],
        );
        let _ = h.with_num_gpus(4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn with_gpu_rejects_out_of_range_index() {
        let _ = Platform::default().with_gpu(1, GpuContext::default());
    }
}
