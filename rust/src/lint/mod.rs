//! `gcaps lint`: a zero-dependency invariant lint pass over this
//! crate's own sources.
//!
//! The repo's correctness story rests on a handful of source-level
//! invariants that `rustc` cannot see — saturating `Time` arithmetic,
//! panic-free always-on paths, deterministic iteration in anything
//! that writes results, poison-recovering lock access, and no wall
//! clocks outside the measurement modules. Each was established by an
//! earlier change and then re-broken (or nearly) by later ones; this
//! module mechanizes them so the build, not review vigilance, holds
//! the line.
//!
//! Pipeline: [`lexer`] turns each `.rs` file into a comment- and
//! literal-stripped token stream with `line:col` positions and
//! `#[cfg(test)]` gating; the [`rules`] run over that stream; the
//! driver here filters `// gcaps-lint: allow(rule) -- reason` escapes,
//! sorts findings, and diffs them against the committed exact-match
//! [`baseline`]. `gcaps lint` exits nonzero on any finding not in the
//! baseline.

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{all_rules, rule_ids, Rule};

/// One lint finding, anchored to a root-relative file and a 1-based
/// `line:col`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    /// The trimmed source line (tabs flattened, capped at 120 chars).
    pub snippet: String,
}

impl Finding {
    /// Canonical one-line rendering; also the baseline match key and
    /// the `--format text` output line.
    pub fn render(&self) -> String {
        format!(
            "{}:{}:{}: {}: {}",
            self.file, self.line, self.col, self.rule, self.snippet
        )
    }

    /// One JSON object per line for `--format jsonl`.
    pub fn render_jsonl(&self) -> String {
        format!(
            "{{\"file\":\"{}\",\"line\":{},\"col\":{},\"rule\":\"{}\",\"snippet\":\"{}\"}}",
            json_escape(&self.file),
            self.line,
            self.col,
            self.rule,
            json_escape(&self.snippet)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Recursively collect `.rs` files under `root`, as root-relative
/// `/`-separated paths, sorted so every run (and every platform)
/// visits files in the same order.
pub fn collect_sources(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().and_then(|x| x.to_str()) == Some("rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut files = Vec::new();
    walk(root, &mut files)?;
    Ok(files)
}

fn rel_slash(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint every `.rs` file under `root` with the given rules, returning
/// allow-filtered findings sorted by `(file, line, col, rule)`.
pub fn lint_tree(root: &Path, rules: &[Box<dyn Rule>]) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for path in collect_sources(root)? {
        let text = fs::read_to_string(&path)?;
        let rel = rel_slash(root, &path);
        let file = lexer::lex(&rel, &text);
        for rule in rules {
            if !rule.applies(&rel) {
                continue;
            }
            let mut out = Vec::new();
            rule.check(&file, &mut out);
            out.retain(|f| !file.allows(f.line, f.rule));
            findings.extend(out);
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.col, a.rule).cmp(&(b.file.as_str(), b.line, b.col, b.rule))
    });
    Ok(findings)
}

/// Lint with every rule. The entry point for the CLI and the
/// self-clean test.
pub fn lint_all(root: &Path) -> io::Result<Vec<Finding>> {
    lint_tree(root, &all_rules())
}

/// Split `findings` against a baseline: `(new, stale)` where `new` is
/// findings absent from the baseline (these fail the lint) and `stale`
/// is baseline lines no current finding matches (these mean the
/// baseline needs regenerating).
pub fn diff_baseline(
    findings: &[Finding],
    base: &std::collections::BTreeSet<String>,
) -> (Vec<Finding>, Vec<String>) {
    let rendered: std::collections::BTreeSet<String> =
        findings.iter().map(|f| f.render()).collect();
    let new = findings.iter().filter(|f| !base.contains(&f.render())).cloned().collect();
    let stale = base.iter().filter(|l| !rendered.contains(*l)).cloned().collect();
    (new, stale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_escapes_quotes_and_backslashes() {
        let f = Finding {
            file: "a.rs".to_string(),
            line: 3,
            col: 9,
            rule: "panic-path",
            snippet: "let s = \"x\\n\";".to_string(),
        };
        let j = f.render_jsonl();
        assert!(j.contains("\\\"x\\\\n\\\""), "{j}");
    }

    #[test]
    fn diff_baseline_splits_new_and_stale() {
        let f = Finding {
            file: "a.rs".to_string(),
            line: 1,
            col: 1,
            rule: "wall-clock",
            snippet: "Instant::now();".to_string(),
        };
        let mut base = std::collections::BTreeSet::new();
        base.insert("gone.rs:9:9: panic-path: old".to_string());
        let (new, stale) = diff_baseline(&[f.clone()], &base);
        assert_eq!(new, vec![f]);
        assert_eq!(stale, vec!["gone.rs:9:9: panic-path: old".to_string()]);
    }
}
