//! Exact-match finding baselines.
//!
//! A baseline is the set of findings the repo has consciously decided
//! to live with (e.g. `panic-path` hits in code whose invariants make
//! the index provably in-bounds). `gcaps lint` fails only on findings
//! *not* in the baseline, so new violations cannot ride in silently,
//! while `--write-baseline` regenerates the file deterministically and
//! CI compares it byte-for-byte against the committed copy — a stale
//! baseline (fixed findings still listed, or new ones absorbed without
//! review) is itself a failure.
//!
//! Matching is exact on the rendered finding line
//! (`file:line:col: rule: snippet`). That is intentionally brittle:
//! editing a baselined line — even reindenting it — evicts it from the
//! baseline and forces a fresh look.

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::Path;

use super::Finding;

const HEADER: &str = "\
# gcaps lint baseline -- accepted findings, exact-match by line.
# Regenerate with `gcaps lint --write-baseline`; CI diffs this file
# byte-for-byte against a fresh run. See README.md#lint.
";

/// Load a baseline file into a set of rendered finding lines.
/// A missing file is an empty baseline, not an error.
pub fn load(path: &Path) -> io::Result<BTreeSet<String>> {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(BTreeSet::new()),
        Err(e) => return Err(e),
    };
    Ok(text
        .lines()
        .map(str::trim_end)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect())
}

/// Render the canonical baseline file contents for `findings`
/// (assumed already sorted by the driver).
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::from(HEADER);
    for f in findings {
        out.push_str(&f.render());
        out.push('\n');
    }
    out
}

pub fn write(path: &Path, findings: &[Finding]) -> io::Result<()> {
    fs::write(path, render(findings))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(file: &str, line: u32) -> Finding {
        Finding {
            file: file.to_string(),
            line,
            col: 7,
            rule: "panic-path",
            snippet: "let x = v[0];".to_string(),
        }
    }

    #[test]
    fn round_trip_preserves_every_finding() {
        let findings = vec![f("a.rs", 1), f("b.rs", 2)];
        let dir = std::env::temp_dir().join("gcaps_lint_baseline_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.txt");
        write(&path, &findings).unwrap();
        let set = load(&path).unwrap();
        assert_eq!(set.len(), 2);
        for x in &findings {
            assert!(set.contains(&x.render()), "{} missing", x.render());
        }
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_empty_baseline() {
        let set = load(Path::new("/nonexistent/gcaps/baseline.txt")).unwrap();
        assert!(set.is_empty());
    }

    #[test]
    fn header_and_blank_lines_are_skipped() {
        let dir = std::env::temp_dir().join("gcaps_lint_baseline_test2");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("baseline.txt");
        fs::write(&path, "# comment\n\na.rs:1:7: panic-path: let x = v[0];\n").unwrap();
        let set = load(&path).unwrap();
        assert_eq!(set.len(), 1);
        fs::remove_file(&path).unwrap();
    }
}
