//! A lightweight Rust lexer for `gcaps lint`: just enough tokenization
//! to run source-level invariant rules — comments, string/char/byte
//! literals and lifetimes are stripped (they can never trigger a
//! rule), every surviving token carries its `line:column`, and a
//! post-pass marks the token ranges gated by `#[cfg(test)]`/`#[test]`
//! so rules can skip test code.
//!
//! This is deliberately NOT a full Rust lexer: no token trees, no
//! nested-generics disambiguation, no edition awareness. The rules
//! only need token adjacency plus three properties the quick-and-dirty
//! approaches get wrong — raw strings (`r"\"` would desynchronize an
//! escape-aware scanner), nested block comments, and `'a` lifetimes vs
//! `'a'` char literals.

/// Token class. Punctuation keeps multi-character operators (`+=`,
/// `::`, `->`, …) as single tokens so rules can tell `+` from `+=`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Punct,
}

/// One token: kind, verbatim text, 1-based position of its first
/// character, and whether it sits inside `#[cfg(test)]`-gated code.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
    pub in_test: bool,
}

/// A lexed source file, ready for the rules: the raw lines (for
/// snippets), the token stream, and the `// gcaps-lint: allow(rule) --
/// reason` escapes collected from comments.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the linted source root, `/`-separated.
    pub rel_path: String,
    pub lines: Vec<String>,
    pub tokens: Vec<Tok>,
    /// `(line, rule)` pairs suppressed by an allow comment. A trailing
    /// comment covers its own line; a whole-line comment covers the
    /// next line too.
    pub allows: Vec<(u32, String)>,
}

impl SourceFile {
    pub fn allows(&self, line: u32, rule: &str) -> bool {
        self.allows.iter().any(|(l, r)| *l == line && r == rule)
    }
}

/// Reserved words: an identifier position check must not mistake
/// `in [0, 1]` or `return [..]` for slice indexing.
pub const KEYWORDS: &[&str] = &[
    "as", "async", "await", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type",
    "union", "unsafe", "use", "where", "while",
];

pub fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

/// Multi-character operators, longest first (the lexer tries each
/// prefix in order).
const OPS3: &[&str] = &["..=", "<<=", ">>=", "..."];
const OPS2: &[&str] = &[
    "::", "->", "=>", "..", "+=", "-=", "*=", "/=", "%=", "==", "!=", "<=", ">=", "&&", "||",
    "<<", ">>", "|=", "&=", "^=",
];

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    col: u32,
    toks: Vec<Tok>,
    allows: Vec<(u32, String)>,
    /// Line number of the last emitted token (to tell whole-line
    /// comments from trailing ones).
    last_tok_line: u32,
}

impl Lexer {
    fn new(text: &str) -> Lexer {
        Lexer {
            chars: text.chars().collect(),
            pos: 0,
            line: 1,
            col: 1,
            toks: Vec::new(),
            allows: Vec::new(),
            last_tok_line: 0,
        }
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    /// Consume one char, updating line/col.
    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn emit(&mut self, kind: TokKind, text: String, line: u32, col: u32) {
        self.last_tok_line = line;
        self.toks.push(Tok { kind, text, line, col, in_test: false });
    }

    /// Consume a `//` line comment (both slashes already peeked, not
    /// consumed) and record any allow escape it carries.
    fn line_comment(&mut self) {
        let line = self.line;
        let whole_line = self.last_tok_line != line;
        let mut body = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            body.push(c);
            self.bump();
        }
        for rule in parse_allow(&body) {
            self.allows.push((line, rule.clone()));
            if whole_line {
                self.allows.push((line + 1, rule));
            }
        }
    }

    /// Consume a (nested) block comment; `/*` not yet consumed.
    fn block_comment(&mut self) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some('/'), Some('*')) => {
                    self.bump();
                    self.bump();
                    depth += 1;
                }
                (Some('*'), Some('/')) => {
                    self.bump();
                    self.bump();
                    depth -= 1;
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => break,
            }
        }
    }

    /// Consume a normal (escape-aware) string; opening quote not yet
    /// consumed.
    fn string(&mut self) {
        self.bump();
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => break,
                _ => {}
            }
        }
    }

    /// Consume a raw string `r"…"` / `r#"…"#…`; the `r`/`br` ident is
    /// already consumed, `#`s and quote are not.
    fn raw_string(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        if self.peek(0) != Some('"') {
            return; // not actually a raw string; nothing consumed but #s
        }
        self.bump();
        loop {
            match self.bump() {
                None => break,
                Some('"') => {
                    let mut ok = true;
                    for k in 0..hashes {
                        if self.peek(k) != Some('#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        for _ in 0..hashes {
                            self.bump();
                        }
                        break;
                    }
                }
                Some(_) => {}
            }
        }
    }

    /// At a `'`: char literal (consumed, no token) or lifetime
    /// (consumed, no token).
    fn quote(&mut self) {
        if self.peek(1) == Some('\\') {
            // Escaped char literal: consume to the closing quote.
            self.bump(); // '
            self.bump(); // \
            self.bump(); // the escaped char
            while let Some(c) = self.bump() {
                if c == '\'' {
                    break;
                }
            }
        } else if self.peek(2) == Some('\'') && self.peek(1).is_some() {
            self.bump();
            self.bump();
            self.bump();
        } else {
            // Lifetime: ' plus ident chars, no closing quote.
            self.bump();
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    self.bump();
                } else {
                    break;
                }
            }
        }
    }

    fn ident(&mut self) {
        let (line, col) = (self.line, self.col);
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        // Raw / byte string or byte char prefixes swallow the literal.
        let next = self.peek(0);
        if (text == "r" || text == "br") && (next == Some('"') || next == Some('#')) {
            self.raw_string();
            return;
        }
        if text == "b" && next == Some('"') {
            self.string();
            return;
        }
        if text == "b" && next == Some('\'') {
            self.quote();
            return;
        }
        self.emit(TokKind::Ident, text, line, col);
    }

    fn number(&mut self) {
        let (line, col) = (self.line, self.col);
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                // Exponent sign: 1e-9 / 2.5E+3 stays one number token.
                if (c == 'e' || c == 'E')
                    && !text.starts_with("0x")
                    && matches!(self.peek(1), Some('+') | Some('-'))
                    && self.peek(2).is_some_and(|d| d.is_ascii_digit())
                {
                    text.push(c);
                    self.bump();
                    text.push(self.peek(0).expect("sign peeked above"));
                    self.bump();
                    continue;
                }
                text.push(c);
                self.bump();
            } else if c == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.emit(TokKind::Number, text, line, col);
    }

    fn punct(&mut self) {
        let (line, col) = (self.line, self.col);
        let mut probe = String::new();
        for k in 0..3 {
            match self.peek(k) {
                Some(c) => probe.push(c),
                None => break,
            }
        }
        for op in OPS3 {
            if probe.starts_with(op) {
                for _ in 0..3 {
                    self.bump();
                }
                self.emit(TokKind::Punct, op.to_string(), line, col);
                return;
            }
        }
        for op in OPS2 {
            if probe.starts_with(op) {
                self.bump();
                self.bump();
                self.emit(TokKind::Punct, op.to_string(), line, col);
                return;
            }
        }
        let c = self.bump().expect("punct present");
        self.emit(TokKind::Punct, c.to_string(), line, col);
    }

    fn run(&mut self) {
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                self.string();
            } else if c == '\'' {
                self.quote();
            } else if c.is_alphabetic() || c == '_' {
                self.ident();
            } else if c.is_ascii_digit() {
                self.number();
            } else if c.is_whitespace() {
                self.bump();
            } else {
                self.punct();
            }
        }
    }
}

/// Parse the rule list out of a `gcaps-lint: allow(a, b) -- reason`
/// comment body. The ` -- reason` part is mandatory: an allow without
/// a recorded justification does not suppress anything.
fn parse_allow(comment: &str) -> Vec<String> {
    let Some(at) = comment.find("gcaps-lint: allow(") else {
        return Vec::new();
    };
    let rest = &comment[at + "gcaps-lint: allow(".len()..];
    let Some(close) = rest.find(')') else {
        return Vec::new();
    };
    if !rest[close..].contains("--") {
        return Vec::new();
    }
    rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Mark every token gated by `#[cfg(test)]` / `#[test]` (attribute,
/// any stacked attributes, and the item's body through its closing
/// brace or terminating semicolon) as `in_test`.
fn mark_test_code(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && i + 1 < toks.len() && toks[i + 1].text == "[" {
            // Find the attribute's closing bracket.
            let mut depth = 0i32;
            let mut j = i + 1;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            if j >= toks.len() {
                return;
            }
            let has = |name: &str| {
                toks[i..=j].iter().any(|t| t.kind == TokKind::Ident && t.text == name)
            };
            if has("test") && !has("not") {
                // Skip stacked attributes after this one.
                let mut k = j + 1;
                while k + 1 < toks.len() && toks[k].text == "#" && toks[k + 1].text == "[" {
                    let mut d = 0i32;
                    let mut m = k + 1;
                    while m < toks.len() {
                        match toks[m].text.as_str() {
                            "[" => d += 1,
                            "]" => {
                                d -= 1;
                                if d == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        m += 1;
                    }
                    k = m + 1;
                }
                // Mark through the item's body: first `{…}` block, or a
                // `;` that arrives before any brace (e.g. `mod tests;`).
                let mut end = toks.len() - 1;
                let mut brace = 0i32;
                let mut seen_brace = false;
                let mut m = k;
                while m < toks.len() {
                    match toks[m].text.as_str() {
                        "{" => {
                            brace += 1;
                            seen_brace = true;
                        }
                        "}" => {
                            brace -= 1;
                            if seen_brace && brace == 0 {
                                end = m;
                                break;
                            }
                        }
                        ";" if !seen_brace => {
                            end = m;
                            break;
                        }
                        _ => {}
                    }
                    m += 1;
                }
                for t in toks[i..=end.min(toks.len() - 1)].iter_mut() {
                    t.in_test = true;
                }
                i = end + 1;
                continue;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
}

/// Lex one file into a [`SourceFile`].
pub fn lex(rel_path: &str, text: &str) -> SourceFile {
    let mut lx = Lexer::new(text);
    lx.run();
    let mut tokens = lx.toks;
    mark_test_code(&mut tokens);
    SourceFile {
        rel_path: rel_path.to_string(),
        lines: text.lines().map(|l| l.to_string()).collect(),
        tokens,
        allows: lx.allows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex("x.rs", src).tokens.iter().map(|t| t.text.clone()).collect()
    }

    #[test]
    fn strings_chars_comments_stripped() {
        let toks = texts("let a = \"x + y\"; // c + d\n let b = 'z'; /* e * f */ b");
        assert_eq!(toks, vec!["let", "a", "=", ";", "let", "b", "=", ";", "b"]);
    }

    #[test]
    fn raw_string_with_backslash_does_not_desync() {
        let toks = texts("let re = r\"\\\"; after");
        assert_eq!(toks, vec!["let", "re", "=", ";", "after"]);
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let toks = texts("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(toks.contains(&"str".to_string()));
        assert!(toks.contains(&"->".to_string()));
    }

    #[test]
    fn multichar_ops_stay_single_tokens() {
        let toks = texts("a += b; c => d; e..=f; g::h");
        assert!(toks.contains(&"+=".to_string()));
        assert!(toks.contains(&"=>".to_string()));
        assert!(toks.contains(&"..=".to_string()));
        assert!(toks.contains(&"::".to_string()));
    }

    #[test]
    fn positions_are_one_based_line_col() {
        let f = lex("x.rs", "ab\n  cd + e");
        assert_eq!((f.tokens[0].line, f.tokens[0].col), (1, 1));
        assert_eq!((f.tokens[1].line, f.tokens[1].col), (2, 3));
        assert_eq!(f.tokens[2].text, "+");
        assert_eq!((f.tokens[2].line, f.tokens[2].col), (2, 6));
    }

    #[test]
    fn cfg_test_block_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { a + b }\n}\nfn after() {}";
        let f = lex("x.rs", src);
        let live = f.tokens.iter().find(|t| t.text == "live").unwrap();
        let plus = f.tokens.iter().find(|t| t.text == "+").unwrap();
        let after = f.tokens.iter().find(|t| t.text == "after").unwrap();
        assert!(!live.in_test);
        assert!(plus.in_test);
        assert!(!after.in_test);
    }

    #[test]
    fn cfg_not_test_is_not_test_gated() {
        let f = lex("x.rs", "#[cfg(not(test))]\nfn real() { x + y }");
        assert!(f.tokens.iter().all(|t| !t.in_test));
    }

    #[test]
    fn allow_comment_requires_reason_and_covers_next_line() {
        let f = lex(
            "x.rs",
            "// gcaps-lint: allow(time-arith) -- bounded by duration\nlet a = b + c;\n\
             let d = e + f; // gcaps-lint: allow(det-iter) -- keyed\nlet g = h; // gcaps-lint: allow(wall-clock)\n",
        );
        assert!(f.allows(1, "time-arith"));
        assert!(f.allows(2, "time-arith"), "whole-line comment covers the next line");
        assert!(f.allows(3, "det-iter"));
        assert!(!f.allows(4, "det-iter"), "trailing comment does not leak downward");
        assert!(!f.allows(4, "wall-clock"), "allow without a -- reason is ignored");
    }
}
