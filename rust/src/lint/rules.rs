//! The five invariant rules. Each rule is a pure function over one
//! file's token stream; the driver in [`crate::lint`] handles the
//! walk, allow-comment filtering, baseline matching, and output.
//!
//! Every rule mechanizes a soundness invariant this repo has already
//! paid for the hard way (see EXPERIMENTS.md §Invariants for the
//! per-rule history):
//!
//! - `time-arith`: bare `+`/`-`/`*` on `Time` wraps at `u64::MAX` and
//!   a wrapped response time is a *tiny* (unsound) bound.
//! - `panic-path`: a panic in `serve/` or `coordinator/` poisons locks
//!   and takes down the admission server or the live executive.
//! - `det-iter`: HashMap iteration order leaks into result CSVs and
//!   breaks run-to-run determinism.
//! - `lock-hygiene`: `.lock().unwrap()` turns one panicked holder into
//!   a crash cascade; `lock_or_recover` is the sanctioned form.
//! - `wall-clock`: `Instant::now` outside the measurement modules
//!   smuggles nondeterminism into what must be a pure function of the
//!   taskset.

use super::lexer::{is_keyword, SourceFile, Tok, TokKind};
use super::Finding;

/// A lint rule over one lexed file.
pub trait Rule {
    /// Stable rule id, used in output, baselines and allow comments.
    fn id(&self) -> &'static str;
    /// One-line description for `gcaps lint --help`-style listings.
    fn about(&self) -> &'static str;
    /// Whether this rule runs on the given root-relative path.
    fn applies(&self, rel_path: &str) -> bool;
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>);
}

/// All rules, in id order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(DetIter),
        Box::new(LockHygiene),
        Box::new(PanicPath),
        Box::new(TimeArith),
        Box::new(WallClock),
    ]
}

pub fn rule_ids() -> Vec<&'static str> {
    all_rules().iter().map(|r| r.id()).collect()
}

fn finding(file: &SourceFile, rule: &'static str, tok: &Tok) -> Finding {
    let raw = file
        .lines
        .get(tok.line as usize - 1)
        .map(|s| s.as_str())
        .unwrap_or("");
    let mut snippet: String = raw.trim().replace('\t', " ");
    if snippet.chars().count() > 120 {
        snippet = snippet.chars().take(117).collect::<String>() + "...";
    }
    Finding {
        file: file.rel_path.clone(),
        line: tok.line,
        col: tok.col,
        rule,
        snippet,
    }
}

/// Operand-shaped token: something a *binary* operator could follow.
/// Excludes keywords so `in [`, `return [` or `match x { _ =>` never
/// read as indexing/arithmetic.
fn operand_like(t: &Tok) -> bool {
    match t.kind {
        TokKind::Ident => !is_keyword(&t.text),
        TokKind::Number => true,
        TokKind::Punct => t.text == ")" || t.text == "]",
    }
}

// ---------------------------------------------------------------- time-arith

/// Identifiers that carry `Time` (µs) values in `sim/` and
/// `analysis/`. Curated, not inferred: the lexer has no types, so the
/// rule keys on the domain vocabulary these modules already use. Kept
/// sorted for the reader; membership is a linear scan (streams are
/// short).
const TIME_VOCAB: &[&str] = &[
    "abs_deadline",
    "base",
    "blocking",
    "budget",
    "c_gm",
    "cpu_rem",
    "deadline",
    "demand",
    "drv_started",
    "dt",
    "duration",
    "elapsed_us",
    "eps",
    "epsilon",
    "gpu_rem",
    "horizon",
    "hp_const",
    "jitter",
    "lp_max",
    "makespan",
    "own",
    "period",
    "release",
    "resp",
    "response",
    "slack",
    "slice_rem",
    "span",
    "switch_rem",
    "theta",
    "wcet",
];

fn is_time_word(s: &str) -> bool {
    TIME_VOCAB.contains(&s)
}

/// How far (in tokens) the operand scan walks away from the operator.
const ARITH_SCAN: usize = 12;

struct TimeArith;

impl TimeArith {
    /// Scan left from the operator for a Time-vocabulary identifier,
    /// staying inside the current expression.
    fn timeish_left(toks: &[Tok], op: usize) -> bool {
        let mut depth = 0i32;
        let mut steps = 0usize;
        let mut j = op;
        while j > 0 && steps < ARITH_SCAN {
            j -= 1;
            steps += 1;
            let t = &toks[j];
            match t.text.as_str() {
                ")" | "]" => depth += 1,
                "(" | "[" => {
                    depth -= 1;
                    if depth < 0 {
                        return false;
                    }
                }
                ";" | "{" | "}" | "=" | "=>" | "return" | "let" => return false,
                "," if depth == 0 => return false,
                _ => {}
            }
            if t.kind == TokKind::Ident && is_time_word(&t.text) {
                return true;
            }
        }
        false
    }

    /// Mirror-image scan to the right of the operator.
    fn timeish_right(toks: &[Tok], op: usize) -> bool {
        let mut depth = 0i32;
        let mut steps = 0usize;
        let mut j = op;
        while j + 1 < toks.len() && steps < ARITH_SCAN {
            j += 1;
            steps += 1;
            let t = &toks[j];
            match t.text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => {
                    depth -= 1;
                    if depth < 0 {
                        return false;
                    }
                }
                ";" | "{" | "}" | "=" => return false,
                "," if depth == 0 => return false,
                _ => {}
            }
            if t.kind == TokKind::Ident && is_time_word(&t.text) {
                return true;
            }
        }
        false
    }
}

impl Rule for TimeArith {
    fn id(&self) -> &'static str {
        "time-arith"
    }
    fn about(&self) -> &'static str {
        "bare +/-/* on Time-carrying expressions (use saturating_* so overflow pins, not wraps)"
    }
    fn applies(&self, rel_path: &str) -> bool {
        rel_path.starts_with("sim/") || rel_path.starts_with("analysis/")
    }
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>) {
        let toks = &file.tokens;
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Punct {
                continue;
            }
            match t.text.as_str() {
                "+" | "-" | "*" => {
                    // Binary position only: `-x`, `*ptr`, `&*g` have a
                    // non-operand (or nothing) on the left.
                    if i == 0 || !operand_like(&toks[i - 1]) {
                        continue;
                    }
                }
                "+=" | "-=" | "*=" => {}
                _ => continue,
            }
            if Self::timeish_left(toks, i) || Self::timeish_right(toks, i) {
                out.push(finding(file, self.id(), t));
            }
        }
    }
}

// ---------------------------------------------------------------- panic-path

struct PanicPath;

impl Rule for PanicPath {
    fn id(&self) -> &'static str {
        "panic-path"
    }
    fn about(&self) -> &'static str {
        "unwrap/expect/panic!/slice-indexing in always-on code (serve/, coordinator/)"
    }
    fn applies(&self, rel_path: &str) -> bool {
        rel_path.starts_with("serve/") || rel_path.starts_with("coordinator/")
    }
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>) {
        let toks = &file.tokens;
        let text = |k: usize| toks.get(k).map(|t| t.text.as_str()).unwrap_or("");
        for (i, t) in toks.iter().enumerate() {
            if t.in_test {
                continue;
            }
            match t.kind {
                TokKind::Ident => {
                    let prev_dot = i > 0 && toks[i - 1].text == ".";
                    if t.text == "unwrap" && prev_dot && text(i + 1) == "(" && text(i + 2) == ")"
                    {
                        out.push(finding(file, self.id(), t));
                    } else if t.text == "expect" && prev_dot && text(i + 1) == "(" {
                        out.push(finding(file, self.id(), t));
                    } else if matches!(
                        t.text.as_str(),
                        "panic" | "unreachable" | "todo" | "unimplemented"
                    ) && text(i + 1) == "!"
                    {
                        out.push(finding(file, self.id(), t));
                    }
                }
                TokKind::Punct if t.text == "[" => {
                    // Indexing: `expr[`, i.e. an operand directly left.
                    // Attribute `#[`, macro `vec![`, types and slice
                    // patterns all fail the operand test.
                    if i > 0 && operand_like(&toks[i - 1]) && toks[i - 1].text != "]" {
                        // `x[0][1]`: flag once per index chain start is
                        // enough noise-wise, but a second `[` after `]`
                        // IS another index — keep it simple and flag
                        // only ident/paren-based heads.
                        out.push(finding(file, self.id(), t));
                    } else if i > 0 && toks[i - 1].text == "]" {
                        out.push(finding(file, self.id(), t));
                    }
                }
                _ => {}
            }
        }
    }
}

// ------------------------------------------------------------------ det-iter

/// Methods whose results depend on hash iteration order.
const ORDER_DEPENDENT: &[&str] = &[
    "drain", "into_iter", "into_keys", "into_values", "iter", "iter_mut", "keys", "values",
    "values_mut",
];

/// Identifiers within the forward window that signal the order is
/// re-established before use.
const SORTED_NEARBY: &[&str] = &[
    "BTreeMap",
    "BTreeSet",
    "BinaryHeap",
    "sort",
    "sort_by",
    "sort_by_key",
    "sort_unstable",
    "sort_unstable_by",
    "sort_unstable_by_key",
    "sorted",
];

/// How far forward to look for a sort after an order-dependent call.
const SORT_SCAN: usize = 40;

struct DetIter;

impl DetIter {
    /// Collect the names bound to HashMap/HashSet values in this file,
    /// from `let [mut] NAME = … HashMap::new()`-style initializers and
    /// `NAME: [&][mut] [std::collections::] HashMap<…>` ascriptions.
    fn hash_names(toks: &[Tok]) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
                continue;
            }
            // (a) `let [mut] NAME` somewhere left, same statement.
            let mut j = i;
            let mut steps = 0usize;
            while j > 0 && steps < 25 {
                j -= 1;
                steps += 1;
                let u = &toks[j];
                if matches!(u.text.as_str(), ";" | "{" | "}") {
                    break;
                }
                if u.kind == TokKind::Ident && u.text == "let" {
                    let mut k = j + 1;
                    if toks.get(k).is_some_and(|t| t.text == "mut") {
                        k += 1;
                    }
                    if let Some(name) = toks.get(k) {
                        if name.kind == TokKind::Ident && !is_keyword(&name.text) {
                            names.push(name.text.clone());
                        }
                    }
                    break;
                }
            }
            // (b) `NAME : [&] [mut] [std :: collections ::] HashMap`.
            let mut j = i;
            loop {
                if j == 0 {
                    break;
                }
                let u = &toks[j - 1];
                let skippable = u.text == "::"
                    || u.text == "&"
                    || u.text == "mut"
                    || (u.kind == TokKind::Ident
                        && matches!(u.text.as_str(), "std" | "collections"));
                if skippable {
                    j -= 1;
                    continue;
                }
                if u.text == ":" && j >= 2 {
                    let name = &toks[j - 2];
                    if name.kind == TokKind::Ident && !is_keyword(&name.text) {
                        names.push(name.text.clone());
                    }
                }
                break;
            }
        }
        names.sort();
        names.dedup();
        names
    }

    fn sorted_nearby(toks: &[Tok], from: usize) -> bool {
        for t in toks.iter().skip(from).take(SORT_SCAN) {
            if t.kind == TokKind::Ident && SORTED_NEARBY.contains(&t.text.as_str()) {
                return true;
            }
        }
        false
    }
}

impl Rule for DetIter {
    fn id(&self) -> &'static str {
        "det-iter"
    }
    fn about(&self) -> &'static str {
        "HashMap/HashSet iteration in result-producing modules without a nearby sort"
    }
    fn applies(&self, rel_path: &str) -> bool {
        rel_path.starts_with("sim/")
            || rel_path.starts_with("analysis/")
            || rel_path.starts_with("sweep/")
            || rel_path.starts_with("experiments/")
    }
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>) {
        let toks = &file.tokens;
        let names = Self::hash_names(toks);
        if names.is_empty() {
            return;
        }
        let text = |k: usize| toks.get(k).map(|t| t.text.as_str()).unwrap_or("");
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident || !names.contains(&t.text) {
                continue;
            }
            // `name.iter()` and friends.
            if text(i + 1) == "."
                && toks
                    .get(i + 2)
                    .is_some_and(|m| ORDER_DEPENDENT.contains(&m.text.as_str()))
                && !Self::sorted_nearby(toks, i + 3)
            {
                out.push(finding(file, self.id(), t));
                continue;
            }
            // `for k in [&[mut]] name` implicit iteration.
            let mut j = i;
            if j > 0 && toks[j - 1].text == "mut" {
                j -= 1;
            }
            if j > 0 && toks[j - 1].text == "&" {
                j -= 1;
            }
            if j > 0 && toks[j - 1].text == "in" && !Self::sorted_nearby(toks, i + 1) {
                out.push(finding(file, self.id(), t));
            }
        }
    }
}

// -------------------------------------------------------------- lock-hygiene

struct LockHygiene;

impl Rule for LockHygiene {
    fn id(&self) -> &'static str {
        "lock-hygiene"
    }
    fn about(&self) -> &'static str {
        "bare .lock().unwrap()/.expect(); use sweep::memo::lock_or_recover"
    }
    fn applies(&self, _rel_path: &str) -> bool {
        true
    }
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>) {
        let toks = &file.tokens;
        let text = |k: usize| toks.get(k).map(|t| t.text.as_str()).unwrap_or("");
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident || t.text != "lock" {
                continue;
            }
            if i == 0 || toks[i - 1].text != "." {
                continue;
            }
            if text(i + 1) == "(" && text(i + 2) == ")" && text(i + 3) == "." {
                let m = text(i + 4);
                if (m == "unwrap" || m == "expect") && text(i + 5) == "(" {
                    out.push(finding(file, self.id(), t));
                }
            }
        }
    }
}

// ---------------------------------------------------------------- wall-clock

/// Files whose whole purpose is timing the host.
const CLOCK_OK: &[&str] = &["serve/counters.rs", "util/bench.rs"];

struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "wall-clock"
    }
    fn about(&self) -> &'static str {
        "Instant::now/SystemTime::now outside util/bench and serve/counters"
    }
    fn applies(&self, rel_path: &str) -> bool {
        !CLOCK_OK.contains(&rel_path)
    }
    fn check(&self, file: &SourceFile, out: &mut Vec<Finding>) {
        let toks = &file.tokens;
        let text = |k: usize| toks.get(k).map(|t| t.text.as_str()).unwrap_or("");
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident {
                continue;
            }
            if (t.text == "Instant" || t.text == "SystemTime")
                && text(i + 1) == "::"
                && text(i + 2) == "now"
            {
                out.push(finding(file, self.id(), t));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn run_rule(rule: &dyn Rule, rel: &str, src: &str) -> Vec<Finding> {
        let file = lex(rel, src);
        let mut out = Vec::new();
        if rule.applies(rel) {
            rule.check(&file, &mut out);
        }
        out.retain(|f| !file.allows(f.line, f.rule));
        out
    }

    #[test]
    fn time_arith_catches_release_plus_deadline() {
        let out = run_rule(
            &TimeArith,
            "sim/engine.rs",
            "fn f(release: Time, deadline: Time) -> Time { release + deadline }",
        );
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "time-arith");
    }

    #[test]
    fn time_arith_ignores_unary_and_saturating() {
        let out = run_rule(
            &TimeArith,
            "analysis/terms.rs",
            "fn f(deadline: Time) -> Time { deadline.saturating_add(deadline) }",
        );
        assert!(out.is_empty());
        let out = run_rule(&TimeArith, "sim/engine.rs", "let x = -(1i64); let y = *ptr;");
        assert!(out.is_empty());
    }

    #[test]
    fn time_arith_out_of_scope_path_is_ignored() {
        let out = run_rule(&TimeArith, "serve/server.rs", "let x = release + deadline;");
        assert!(out.is_empty());
    }

    #[test]
    fn panic_path_catches_unwrap_and_indexing() {
        let src = "fn f(v: &[u32]) -> u32 { let x = g().unwrap(); v[0] + x }";
        let out = run_rule(&PanicPath, "serve/server.rs", src);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn panic_path_skips_macros_attrs_and_tests() {
        let src = "#[derive(Debug)]\nstruct S;\nfn f() { let v = vec![1]; }\n\
                   #[cfg(test)]\nmod t { fn g() { h().unwrap(); } }";
        let out = run_rule(&PanicPath, "coordinator/arbiter.rs", src);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn det_iter_catches_unsorted_map_iteration() {
        let src = "fn f() { let mut m = HashMap::new(); for (k, v) in &m { use_it(k, v); } }";
        let out = run_rule(&DetIter, "sweep/mod.rs", src);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn det_iter_accepts_sorted_collect() {
        let src =
            "fn f() { let m = HashMap::new(); let mut v: Vec<_> = m.iter().collect(); v.sort(); }";
        let out = run_rule(&DetIter, "experiments/mod.rs", src);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn lock_hygiene_catches_bare_lock_unwrap() {
        let out = run_rule(&LockHygiene, "runtime/mod.rs", "let g = m.lock().unwrap();");
        assert_eq!(out.len(), 1);
        let out = run_rule(
            &LockHygiene,
            "runtime/mod.rs",
            "let g = m.lock().unwrap_or_else(|e| e.into_inner());",
        );
        assert!(out.is_empty());
    }

    #[test]
    fn wall_clock_scoped_to_measurement_files() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(run_rule(&WallClock, "sim/engine.rs", src).len(), 1);
        assert!(run_rule(&WallClock, "util/bench.rs", src).is_empty());
    }

    #[test]
    fn allow_comment_suppresses() {
        let src = "fn f() { let t = Instant::now(); // gcaps-lint: allow(wall-clock) -- timing\n }";
        assert!(run_rule(&WallClock, "sim/engine.rs", src).is_empty());
    }
}
